//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see DESIGN.md §2 for why not serialized protos) and
//! executes them on the XLA CPU client from the L3 hot path. Python never
//! runs at train time.
//!
//! * [`Manifest`] — `artifacts/manifest.json`, describing each HLO entry
//!   point (input/output dtypes+shapes) plus model metadata (parameter
//!   counts, init-weight files).
//! * [`XlaRuntime`] — PJRT client + compiled-executable cache.
//! * [`lm::TransformerLm`] — a [`crate::models::Problem`] backed by the
//!   transformer-LM gradient artifact: the end-to-end path
//!   (rust coordinator → XLA executable → Pallas-kernel HLO).

pub mod lm;
pub mod xla;

use crate::config::json::Json;
use crate::F;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Whether the XLA backend is real or the offline stub
/// (`rust/src/runtime/xla.rs`). Artifact-gated tests consult this to skip
/// instead of failing on machines without the PJRT bindings.
pub fn xla_available() -> bool {
    xla::AVAILABLE
}

/// One tensor argument/result of an artifact.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            dtype: v.req_str("dtype")?.to_string(),
            shape: v
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("tensor spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
                .collect::<anyhow::Result<_>>()?,
        })
    }
}

/// One compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> anyhow::Result<Self> {
        let specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
            v.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("artifact missing '{key}'"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(Self {
            file: v.req_str("file")?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Transformer-LM metadata recorded by `aot.py`.
#[derive(Clone, Debug)]
pub struct LmMeta {
    pub param_count: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub init_file: String,
}

impl LmMeta {
    fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            param_count: v.req_usize("param_count")?,
            vocab: v.req_usize("vocab")?,
            d_model: v.req_usize("d_model")?,
            n_layers: v.req_usize("n_layers")?,
            n_heads: v.req_usize("n_heads")?,
            seq_len: v.req_usize("seq_len")?,
            batch: v.req_usize("batch")?,
            init_file: v.req_str("init_file")?.to_string(),
        })
    }
}

/// MLP metadata recorded by `aot.py` (used by the L2↔L3 gradient
/// cross-check test).
#[derive(Clone, Debug)]
pub struct MlpMeta {
    pub param_count: usize,
    pub sizes: Vec<usize>,
    pub batch: usize,
    pub init_file: String,
}

impl MlpMeta {
    fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            param_count: v.req_usize("param_count")?,
            sizes: v
                .get("sizes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("mlp meta missing sizes"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad size")))
                .collect::<anyhow::Result<_>>()?,
            batch: v.req_usize("batch")?,
            init_file: v.req_str("init_file")?.to_string(),
        })
    }
}

/// `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Ordered map: artifact compilation and `artifact_names()` listing
    /// follow name order deterministically.
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub lm: Option<LmMeta>,
    pub mlp: Option<MlpMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}; run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let v = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?
        {
            artifacts.insert(name.clone(), ArtifactEntry::from_json(entry)?);
        }
        Ok(Self {
            artifacts,
            lm: v.get("lm").map(LmMeta::from_json).transpose()?,
            mlp: v.get("mlp").map(MlpMeta::from_json).transpose()?,
        })
    }
}

/// An input value for [`XlaRuntime::execute`].
pub enum Arg<'a> {
    F32(&'a [F]),
    I32(&'a [i32]),
}

/// An output value.
#[derive(Clone, Debug, PartialEq)]
pub enum Out {
    F32(Vec<F>),
    I32(Vec<i32>),
}

impl Out {
    pub fn as_f32(&self) -> &[F] {
        match self {
            Out::F32(v) => v,
            Out::I32(_) => panic!("expected f32 output"),
        }
    }

    /// Scalar convenience (losses).
    pub fn scalar_f32(&self) -> F {
        let v = self.as_f32();
        assert_eq!(v.len(), 1, "expected scalar");
        v[0]
    }
}

/// PJRT CPU client plus compiled executables for every manifest entry.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Load the manifest and compile every artifact eagerly (AOT-of-AOT:
    /// the HLO was lowered at build time; PJRT compilation happens once at
    /// startup, never per step).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        let mut executables = BTreeMap::new();
        for (name, entry) in &manifest.artifacts {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling artifact '{name}': {e}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Self { client, manifest, dir, executables })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Read a raw little-endian f32 weight file referenced by the manifest.
    pub fn read_f32_file(&self, rel: &str) -> anyhow::Result<Vec<F>> {
        let bytes = std::fs::read(self.dir.join(rel))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "f32 file length not divisible by 4");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| F::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Execute artifact `name` with `args` (checked against the manifest
    /// specs), returning all tuple outputs.
    pub fn execute(&self, name: &str, args: &[Arg]) -> anyhow::Result<Vec<Out>> {
        let entry = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?;
        let exe = &self.executables[name];
        anyhow::ensure!(
            args.len() == entry.inputs.len(),
            "artifact '{name}' wants {} inputs, got {}",
            entry.inputs.len(),
            args.len()
        );
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(entry.inputs.iter()) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (arg, spec.dtype.as_str()) {
                (Arg::F32(v), "f32") => {
                    anyhow::ensure!(
                        v.len() == spec.elements(),
                        "f32 arg size mismatch for '{name}'"
                    );
                    let l = xla::Literal::vec1(v);
                    if dims.len() == 1 { l } else { l.reshape(&dims).map_err(wrap)? }
                }
                (Arg::I32(v), "i32") => {
                    anyhow::ensure!(
                        v.len() == spec.elements(),
                        "i32 arg size mismatch for '{name}'"
                    );
                    let l = xla::Literal::vec1(v);
                    if dims.len() == 1 { l } else { l.reshape(&dims).map_err(wrap)? }
                }
                _ => anyhow::bail!("arg dtype mismatch for '{name}' (spec {})", spec.dtype),
            };
            literals.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&literals).map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        // aot.py lowers with return_tuple=True → always a tuple literal.
        let parts = result.to_tuple().map_err(wrap)?;
        anyhow::ensure!(
            parts.len() == entry.outputs.len(),
            "artifact '{name}' returned {} outputs, manifest says {}",
            parts.len(),
            entry.outputs.len()
        );
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(entry.outputs.iter()) {
            outs.push(match spec.dtype.as_str() {
                "f32" => Out::F32(lit.to_vec::<F>().map_err(wrap)?),
                "i32" => Out::I32(lit.to_vec::<i32>().map_err(wrap)?),
                other => anyhow::bail!("unsupported output dtype '{other}'"),
            });
        }
        Ok(outs)
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

/// Default artifact directory: `$DORE_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("DORE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            r#"{
            "artifacts": {
                "lm_grad": {
                    "file": "lm_grad.hlo.txt",
                    "inputs": [{"dtype": "f32", "shape": [100]},
                               {"dtype": "i32", "shape": [4, 65]}],
                    "outputs": [{"dtype": "f32", "shape": []},
                                {"dtype": "f32", "shape": [100]}]
                }
            },
            "lm": {"param_count": 100, "vocab": 512, "d_model": 16,
                   "n_layers": 2, "n_heads": 2, "seq_len": 64, "batch": 4,
                   "init_file": "lm_init.bin"}
        }"#,
        )
        .unwrap();
        let e = &m.artifacts["lm_grad"];
        assert_eq!(e.inputs[1].elements(), 4 * 65);
        assert_eq!(e.outputs[0].elements(), 1); // scalar: empty shape
        assert_eq!(m.lm.as_ref().unwrap().vocab, 512);
        assert!(m.mlp.is_none());
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": {"x": {"file": "f"}}}"#).is_err());
    }
}
