//! Transformer-LM [`Problem`] backed by the AOT XLA artifacts — the
//! end-to-end compute path: rust coordinator (L3) → `lm_grad.hlo.txt`
//! (L2 JAX graph) → Pallas matmul kernels lowered inline (L1).
//!
//! The artifact's exported function takes the **flat** parameter vector
//! `f32[d]` plus a token batch `i32[B, T+1]` and returns
//! `(mean CE loss, flat gradient)`, so the distributed algorithms treat the
//! transformer exactly like any other `R^d` objective.

use super::{Arg, Out, XlaRuntime};
use crate::compression::Xoshiro256;
use crate::data::shard_ranges;
use crate::models::Problem;
use crate::F;
use std::path::Path;
use std::sync::Mutex;

pub struct TransformerLm {
    /// PJRT state, serialized behind a mutex (see the SAFETY note on the
    /// `unsafe impl`s below).
    rt: Mutex<XlaRuntime>,
    corpus: Vec<u32>,
    shards: Vec<(usize, usize)>,
    pub param_count: usize,
    pub batch: usize,
    pub seq_len: usize,
    n_workers: usize,
    init: Vec<F>,
    /// Fixed evaluation batch (token windows) for `loss()`.
    eval_tokens: Vec<i32>,
}

// SAFETY: the `xla` crate's wrappers hold raw pointers and are not
// auto-Send/Sync, but the PJRT CPU client is thread-safe for compilation
// and execution (it is the same client JAX uses from multi-threaded
// python). We still serialize all access through the `rt` mutex, so
// cross-thread use is exclusive.
unsafe impl Send for TransformerLm {}
unsafe impl Sync for TransformerLm {}

impl TransformerLm {
    /// `artifact_dir` must contain `lm_grad` + `lm_loss` entries and the
    /// init-weights file (see `python/compile/aot.py`).
    pub fn load(
        artifact_dir: impl AsRef<Path>,
        corpus: Vec<u32>,
        n_workers: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let rt = XlaRuntime::load(artifact_dir)?;
        let meta = rt
            .manifest
            .lm
            .clone()
            .ok_or_else(|| anyhow::anyhow!("manifest has no `lm` section; re-run make artifacts"))?;
        let init = rt.read_f32_file(&meta.init_file)?;
        anyhow::ensure!(
            init.len() == meta.param_count,
            "init file has {} params, manifest says {}",
            init.len(),
            meta.param_count
        );
        let window = meta.seq_len + 1;
        anyhow::ensure!(
            corpus.len() >= n_workers * meta.batch * window,
            "corpus too small for {n_workers} workers"
        );
        let vocab = meta.vocab as u32;
        anyhow::ensure!(corpus.iter().all(|&t| t < vocab), "token out of vocab");
        let shards = shard_ranges(corpus.len(), n_workers);
        // fixed eval batch drawn from the whole corpus
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xe7a1);
        let eval_tokens = sample_windows(&corpus, 0, corpus.len(), meta.batch, window, &mut rng);
        Ok(Self {
            rt: Mutex::new(rt),
            corpus,
            shards,
            param_count: meta.param_count,
            batch: meta.batch,
            seq_len: meta.seq_len,
            n_workers,
            init,
            eval_tokens,
        })
    }
}

/// Sample `batch` contiguous windows of `window` tokens from
/// `corpus[lo..hi]`, flattened row-major as i32.
fn sample_windows(
    corpus: &[u32],
    lo: usize,
    hi: usize,
    batch: usize,
    window: usize,
    rng: &mut Xoshiro256,
) -> Vec<i32> {
    let span = hi - lo;
    assert!(span >= window, "shard smaller than one window");
    let mut out = Vec::with_capacity(batch * window);
    for _ in 0..batch {
        let start = lo + rng.next_below(span - window + 1);
        out.extend(corpus[start..start + window].iter().map(|&t| t as i32));
    }
    out
}

impl Problem for TransformerLm {
    fn dim(&self) -> usize {
        self.param_count
    }

    fn n_workers(&self) -> usize {
        self.n_workers
    }

    fn local_grad(
        &self,
        i: usize,
        x: &[F],
        _minibatch: Option<usize>,
        rng: &mut Xoshiro256,
        out: &mut [F],
    ) {
        let (lo, hi) = self.shards[i];
        let tokens = sample_windows(&self.corpus, lo, hi, self.batch, self.seq_len + 1, rng);
        let rt = self.rt.lock().unwrap();
        let res = rt
            .execute("lm_grad", &[Arg::F32(x), Arg::I32(&tokens)])
            .expect("lm_grad execution");
        match &res[1] {
            Out::F32(g) => out.copy_from_slice(g),
            _ => panic!("lm_grad output 1 must be f32 grad"),
        }
    }

    fn loss(&self, x: &[F]) -> f64 {
        let rt = self.rt.lock().unwrap();
        let res = rt
            .execute("lm_loss", &[Arg::F32(x), Arg::I32(&self.eval_tokens)])
            .expect("lm_loss execution");
        res[0].scalar_f32() as f64
    }

    fn init(&self) -> Vec<F> {
        self.init.clone()
    }

    fn name(&self) -> &str {
        "transformer-lm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_windows_bounds_and_shape() {
        let corpus: Vec<u32> = (0..100).collect();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let w = sample_windows(&corpus, 20, 80, 5, 8, &mut rng);
        assert_eq!(w.len(), 40);
        for row in w.chunks(8) {
            assert!(row[0] >= 20 && row[7] < 80);
            // windows are contiguous
            for j in 1..8 {
                assert_eq!(row[j], row[j - 1] + 1);
            }
        }
    }

    #[test]
    #[should_panic(expected = "shard smaller")]
    fn sample_windows_rejects_tiny_shard() {
        let corpus: Vec<u32> = (0..10).collect();
        let mut rng = Xoshiro256::seed_from_u64(1);
        sample_windows(&corpus, 0, 4, 1, 8, &mut rng);
    }
}
