//! `dore` — launcher CLI for the DORE reproduction.
//!
//! ```text
//! dore train --config job.json [--csv out.csv] [--distributed]
//! dore train --problem linreg --algorithm dore --lr 0.05 --iters 1000 ...
//! dore train --transport tcp --bind 0.0.0.0:7000 ...   # serve a real fleet
//! dore compare --problem linreg --iters 1000       # all 7 algorithms
//! dore bandwidth --dim 11173962                    # Fig. 2 style sweep
//! dore artifacts --dir artifacts                   # inspect AOT artifacts
//! ```
//!
//! Flag parsing ([`dore::cli::Flags`]) is hand-rolled (offline
//! environment, no clap): every flag is `--name value` except bare
//! booleans like `--distributed`. The flag → spec mapping is shared with
//! the `dore-worker` binary through [`dore::cli`], so a master and its
//! remote workers launched with the same flags agree on the spec
//! fingerprint the registration handshake checks.

#![deny(deprecated)]

use dore::algorithms::{AlgorithmKind, HyperParams};
use dore::cli::{apply_spec_overrides, build_problem, train_spec, Flags};
use dore::comm::StragglerSpec;
use dore::config::{JobConfig, ProblemConfig};
use dore::coordinator::tcp::TcpTransport;
use dore::data::synth;
use dore::engine::{MaskLog, MaskSchedule, Participation, Session, SimNet, Threaded, TrainSpec};
use dore::harness::{characterize_round, compare, simulated_iteration_time};
use dore::models::mlp::{Mlp, MlpArch};
use dore::models::Problem;
use dore::runtime::lm::TransformerLm;
use dore::runtime::XlaRuntime;
use std::sync::Arc;

fn problem_from_config(cfg: &ProblemConfig, workers: usize) -> anyhow::Result<Arc<dyn Problem>> {
    Ok(match cfg {
        ProblemConfig::Linreg { rows, dim, lambda, data_seed } => {
            Arc::new(synth::linreg_problem(*rows, *dim, workers, *lambda, *data_seed))
        }
        ProblemConfig::MnistMlp { n_examples, hidden, data_seed } => {
            let (tr, te) = synth::mnist_like(*n_examples, *data_seed).split_test(n_examples / 8);
            let mut sizes = vec![784];
            sizes.extend(hidden);
            sizes.push(10);
            Arc::new(Mlp::new(MlpArch::new(&sizes), tr, Some(te), workers, *data_seed))
        }
        ProblemConfig::CifarMlp { n_examples, hidden, data_seed } => {
            let (tr, te) = synth::cifar_like(*n_examples, *data_seed).split_test(n_examples / 8);
            let mut sizes = vec![3072];
            sizes.extend(hidden);
            sizes.push(10);
            Arc::new(Mlp::new(MlpArch::new(&sizes), tr, Some(te), workers, *data_seed))
        }
        ProblemConfig::TransformerLm { artifact_dir, corpus_len, data_seed } => {
            let corpus = synth::markov_corpus(*corpus_len, 512, *data_seed);
            Arc::new(TransformerLm::load(artifact_dir, corpus, workers, *data_seed)?)
        }
    })
}

fn print_run_summary(m: &dore::metrics::RunMetrics, workers: usize) {
    println!(
        "algo={} rounds={} wall={:.2}s final_loss={:.4e} final_digest={:016x} \
         bits/round/worker={:.0} total_MB={:.2}",
        m.algo,
        m.total_rounds,
        m.wall_seconds,
        m.loss.last().copied().unwrap_or(f64::NAN),
        m.final_model_digest,
        m.bits_per_round_per_worker(workers),
        m.total_bits() as f64 / 8e6,
    );
    if let Some(sim) = m.simulated_seconds {
        let per_round = sim / m.total_rounds.max(1) as f64;
        println!("simulated network time: {sim:.3}s ({per_round:.4} s/round)");
    }
    if m.max_in_flight > 1 {
        println!(
            "pipeline: up to {} rounds in flight, {} stale-gradient rounds",
            m.max_in_flight, m.stale_uplink_rounds
        );
    }
    if m.workers_lost + m.workers_rejoined + m.checkpoints_written > 0 {
        println!(
            "recovery: {} workers lost, {} rejoined, {} checkpoints written",
            m.workers_lost, m.workers_rejoined, m.checkpoints_written
        );
    }
    if let Some(rho) = m.empirical_rate(1e-9) {
        println!("empirical per-round contraction rho = {rho:.5}");
    }
}

const USAGE: &str = "usage: dore <train|compare|bandwidth|artifacts> [--flags]
  train      --config job.json | --problem P --algorithm A --lr F --iters N
             [--alpha F --beta F --eta F --compressor SPEC --prox SPEC
              --schedule SPEC --workers N --minibatch N --eval-every N
              --seed N --stale skip|reuse
              --participation full|k:<K>|dropout:<p>|fastest:<K>
                (fastest:<K> folds the first K arrivals; tcp/simnet only)
              --fault none|rand:<p>:<outage>|crash:<w>@<r>[..<rejoin>],...
              --checkpoint-every K [--checkpoint-path FILE] --resume FILE
              --mask-log FILE (record realized per-round masks)
              --replay-masks FILE (replay a recorded mask log bit-identically)
              --reduce-threads N (master-side sharded reduction; 0 = all cores)
              --pipeline-depth D (in-flight rounds per link; 1 = synchronous)
              --wire-codec fixed|entropy (wire frames; entropy = Huffman/Rice,
                never larger, trajectory-neutral)
              --transport inproc|threads|tcp|simnet
              --bind ADDR (tcp: serve external dore-worker processes on ADDR
                instead of spawning local worker threads)
              [--bandwidth BPS --straggler MULT[:FRAC[:JITTER_S]]]
              --distributed --csv FILE]
  compare    --problem P --lr F --workers N --iters N [--minibatch N --seed N]
  bandwidth  [--dim N --workers N --compute SECS]
  artifacts  [--dir DIR]
  (fleet workers: see the dore-worker binary — dore-worker --connect HOST:PORT
   --slot I --workers N + the master's training flags)";

fn cmd_train(f: &Flags) -> anyhow::Result<()> {
    let (prob, mut spec): (Arc<dyn Problem>, TrainSpec) = if let Some(path) = f.get("config") {
        let job = JobConfig::from_file(path)?;
        let prob = problem_from_config(&job.problem, job.n_workers)?;
        let mut spec = TrainSpec {
            algo: job.algorithm_kind()?,
            hp: job.hyper.to_hyperparams()?,
            iters: job.iters,
            minibatch: job.minibatch,
            eval_every: job.eval_every,
            seed: job.seed,
            wire_codec: job.wire_codec.parse()?,
            ..Default::default()
        };
        // the cross-cutting flag overrides (participation, stale, fault,
        // reduce threads, pipeline depth, wire codec) apply on top of the
        // config file too
        apply_spec_overrides(f, &mut spec)?;
        (prob, spec)
    } else {
        let workers: usize = f.num("workers", 20)?;
        let seed: u64 = f.num("seed", 42)?;
        let prob = build_problem(f.get("problem").unwrap_or("linreg"), workers, seed)?;
        (prob, train_spec(f)?)
    };
    // replay a recorded mask log (e.g. from --mask-log on a fastest:k
    // run): participation becomes the literal recorded schedule, which
    // reproduces the recording run bit-identically on any transport
    if let Some(path) = f.get("replay-masks") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--replay-masks {path}: {e}"))?;
        spec.participation =
            Participation::Recorded(Arc::new(MaskSchedule::parse_log(&text)?));
    }
    let n = prob.n_workers();
    // --transport inproc (default) | threads | tcp | simnet — all produce
    // bit-identical iterates; they differ only in what carries the bytes
    // (and, for simnet, in also advancing a modelled network clock).
    let transport = f.get("transport").unwrap_or(if f.flag("distributed") {
        "threads"
    } else {
        "inproc"
    });
    anyhow::ensure!(
        f.get("straggler").is_none() || transport == "simnet",
        "--straggler models simulated network time and requires --transport simnet"
    );
    anyhow::ensure!(
        f.get("bind").is_none() || transport == "tcp",
        "--bind serves an external socket fleet and requires --transport tcp"
    );
    let mut session = Session::shared(prob).spec(spec);
    // record the realized per-round participation masks (the replay log
    // for --replay-masks; essential for reproducing fastest:k runs, whose
    // masks are arrival data, not a function of the seed)
    if let Some(path) = f.get("mask-log") {
        session = session
            .observer(MaskLog::create(path).map_err(|e| anyhow::anyhow!("--mask-log {path}: {e}"))?);
    }
    // checkpoint cadence (inline transports) + resume (any transport);
    // see the README fault-tolerance section for the semantics
    if let Some(k) = f.get("checkpoint-every") {
        let every: usize = k.parse().map_err(|e| anyhow::anyhow!("--checkpoint-every {k}: {e}"))?;
        session = session.checkpoint_every(every, f.get("checkpoint-path").unwrap_or("dore.ckpt"));
    }
    if let Some(path) = f.get("resume") {
        session = session.resume_from(path);
    }
    let metrics = match transport {
        "inproc" => session.run()?,
        "threads" => session.transport(Threaded::new()).run()?,
        "tcp" => match f.get("bind") {
            // external fleet: bind the given address and wait for n
            // dore-worker processes to register (no local worker threads)
            Some(addr) => {
                let t = TcpTransport::bind(addr)?;
                println!(
                    "master listening on {} — waiting for {n} dore-worker registrations",
                    t.local_addr().expect("bound")
                );
                session.transport(t).run()?
            }
            None => session.transport(TcpTransport::new()).run()?,
        },
        "simnet" => {
            let bw: f64 = f.num("bandwidth", 1e9)?;
            let straggler = match f.get("straggler") {
                None => StragglerSpec::none(),
                Some(s) => s.parse::<StragglerSpec>()?,
            };
            session.transport(SimNet::with_bandwidth(bw).straggler(straggler)).run()?
        }
        other => anyhow::bail!("unknown transport '{other}' (inproc|threads|tcp|simnet)"),
    };
    print_run_summary(&metrics, n);
    if let Some(path) = f.get("csv") {
        metrics.write_csv(std::fs::File::create(path)?)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_compare(f: &Flags) -> anyhow::Result<()> {
    let workers: usize = f.num("workers", 20)?;
    let iters: usize = f.num("iters", 1000)?;
    let seed: u64 = f.num("seed", 42)?;
    let prob = build_problem(f.get("problem").unwrap_or("linreg"), workers, seed)?;
    let template = TrainSpec {
        hp: HyperParams { lr: f.num("lr", 0.05)?, ..HyperParams::paper_defaults() },
        iters,
        minibatch: f.get("minibatch").map(|s| s.parse()).transpose()?,
        eval_every: (iters / 20).max(1),
        seed,
        ..Default::default()
    };
    println!(
        "{:<22}{:>14}{:>14}{:>18}{:>12}",
        "algorithm", "final loss", "dist-to-opt", "bits/rnd/worker", "wall s"
    );
    for (kind, m) in compare(prob.as_ref(), AlgorithmKind::all(), &template) {
        println!(
            "{:<22}{:>14.4e}{:>14.4e}{:>18.0}{:>12.2}",
            kind.name(),
            m.loss.last().copied().unwrap_or(f64::NAN),
            m.dist_to_opt.last().copied().unwrap_or(f64::NAN),
            m.bits_per_round_per_worker(workers),
            m.wall_seconds,
        );
    }
    Ok(())
}

fn cmd_bandwidth(f: &Flags) -> anyhow::Result<()> {
    let dim: usize = f.num("dim", 11_173_962)?;
    let workers: usize = f.num("workers", 10)?;
    let compute: f64 = f.num("compute", 0.18)?;
    let hp = HyperParams::paper_defaults();
    println!("Fig. 2 sweep: d={dim}, n={workers}, compute={compute}s/round");
    println!("{:<12}{:>14}{:>14}{:>14}", "bandwidth", "SGD s/it", "QSGD s/it", "DORE s/it");
    let schemes = [AlgorithmKind::Sgd, AlgorithmKind::Qsgd, AlgorithmKind::Dore];
    let chars: Vec<_> =
        schemes.iter().map(|&a| characterize_round(a, dim, workers, &hp)).collect();
    for bw in [1e9, 500e6, 200e6, 100e6, 50e6, 20e6, 10e6] {
        let mut row = format!("{:<12}", format!("{}Mbps", (bw / 1e6) as u64));
        for (up, down, _) in &chars {
            let t = simulated_iteration_time(*up, *down, compute, bw, workers);
            row += &format!("{t:>14.3}");
        }
        println!("{row}");
    }
    Ok(())
}

fn cmd_artifacts(f: &Flags) -> anyhow::Result<()> {
    let rt = XlaRuntime::load(f.get("dir").unwrap_or("artifacts"))?;
    println!("platform: {}", rt.platform());
    let mut names = rt.artifact_names();
    names.sort();
    for n in names {
        let e = &rt.manifest.artifacts[n];
        let fmt_specs = |specs: &[dore::runtime::TensorSpec]| {
            specs
                .iter()
                .map(|s| format!("{}{:?}", s.dtype, s.shape))
                .collect::<Vec<_>>()
                .join(", ")
        };
        println!("  {n}: {} -> {} ({})", fmt_specs(&e.inputs), fmt_specs(&e.outputs), e.file);
    }
    if let Some(lm) = &rt.manifest.lm {
        println!(
            "lm: {} params, vocab {}, d_model {}, {} layers",
            lm.param_count, lm.vocab, lm.d_model, lm.n_layers
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "compare" => cmd_compare(&flags),
        "bandwidth" => cmd_bandwidth(&flags),
        "artifacts" => cmd_artifacts(&flags),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}
