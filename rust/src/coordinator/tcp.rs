//! TCP master for the round engine: the same master/worker state machines
//! and the same [`crate::engine::Session`] loop as every other transport,
//! but over real sockets — the deployment shape the paper's testbed used
//! (PS + workers on Ethernet).
//!
//! The stack is layered: frames and their serialization live in
//! [`crate::engine::protocol`] (one versioned wire format for every
//! byte-moving transport — see its module docs for the header layout),
//! per-connection machinery (reassembly buffers, writer threads) lives in
//! [`super::link`], and the worker-side session (registration handshake,
//! round schedule, drain) lives in [`super::worker`]. This module is the
//! master: it owns connection admission, round sequencing, and fault
//! bookkeeping.
//!
//! Two deployment modes share all of that:
//!
//! * **Local** ([`TcpTransport::new`]): binds an ephemeral localhost port
//!   and spawns one OS thread per worker, each with its own socket — the
//!   in-tree testing shape.
//! * **External** ([`TcpTransport::bind`]): binds a caller-chosen address
//!   and waits (up to [`TcpTransport::registration_timeout`]) for `n`
//!   `dore-worker` *processes* to register — the real multi-host fleet.
//!   Registration hellos carry the protocol version (checked by the frame
//!   header itself), model dimension, fleet size, and a fingerprint of the
//!   training spec; any mismatch is rejected with an error naming both
//!   sides. At `finish` each worker sends a drain frame carrying its
//!   final-model digest, which the master checks against its own iterate.
//!
//! Pipelining rides the sockets naturally: each worker writes its
//! round-`k` uplink after reading the round-`k − depth` downlink, so up to
//! `depth` uplinks are on the wire per link while the master reduces older
//! rounds. Because a worker emits its uplink frames in round order, the
//! next unread uplink frame on a socket is always the oldest round the
//! master still needs — per-socket sequential reads need no reordering
//! buffer. Downlinks are written by one dedicated writer thread per worker
//! (fed from a depth-bounded channel), so the master's read loop never
//! blocks on a full send buffer.
//!
//! # Speed-aware participation
//!
//! Under [`Participation::Fastest`] every worker computes every round
//! speculatively and the master's poll barrier closes after the first `k`
//! uplinks *arrive* — participation is hardware-driven, not seeded. The
//! downlink then carries the realized mask as a prefix
//! ([`crate::engine::protocol::encode_masked_downlink`]); a worker whose
//! uplink was dropped rewinds to its pre-round snapshot before applying,
//! so its state is bit-identical to having never computed. Stale
//! speculative uplinks left in the socket buffers are discarded at the
//! next round's poll. The realized masks are recorded by the session (run
//! log + checkpoints) and replaying them through
//! [`Participation::Recorded`] reproduces the run bit-identically.
//!
//! # Fault tolerance
//!
//! The master side reads **nonblockingly**: each socket has a reassembly
//! buffer, and [`Transport::poll_uplinks`] returns `None` (the engine
//! yields and re-polls) when a round cannot be resolved within the poll
//! deadline instead of parking the run on a dead `read`. A worker whose
//! connection drops (EOF / reset mid-frame) is **lost**: its replay cache
//! is discarded, the loss is reported through [`Transport::drain_faults`],
//! and the round stalls until a replacement **re-registers** — the
//! listener stays open, and a reconnect hello is answered with a sync
//! frame carrying the resume round plus the master's current model (fed
//! each round via [`Transport::sync_state`]). The rejoined worker starts
//! with fresh (zeroed) residual state — the master's `h`/error state
//! carries what the paper's algebra needs, so training proceeds and the
//! fleet's models stay synchronized — but a run with a real crash is *not*
//! bit-identical to an uninterrupted one; use [`crate::engine::FaultPlan`]
//! for deterministic failure injection and
//! [`crate::engine::Session::checkpoint_every`] for bit-exact kill/resume.
//! [`TcpTransport::respawn_lost`] auto-spawns a local replacement thread
//! for a lost worker (the chaos-test path); without it, a worker that
//! stays lost past [`TcpTransport::reconnect_timeout`] fails the run with
//! an actionable error rather than hanging forever.

use super::link::{close_conn, conn_try_read, read_frame_buffered, spawn_conn, Conn, SockRead};
use super::worker::{tcp_worker_main, WorkerBoot};
use crate::algorithms::{digest_f32, WorkerNode};
use crate::compression::{codec, Compressed};
use crate::engine::protocol::{
    encode_masked_downlink, parse_drain_digest, read_frame, spec_fingerprint, write_frame,
    DownlinkMsg, Frame, FrameKind, HelloBody, SyncBody,
};
use crate::engine::registry;
use crate::engine::transport::{absent_slot_frame, RoundWindow};
use crate::engine::{
    Participation, RoundCtx, StalePolicy, TrainSpec, Transport, TransportFault, UplinkFrame,
    WirePayload,
};
use crate::models::Problem;
use crate::F;
use anyhow::Context as _;
use std::collections::BTreeMap;
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
// lint:allow(wall_clock, socket poll/reconnect deadlines only; timeouts never feed the trajectory)
use std::time::{Duration, Instant};

/// Partially assembled uplink slots of the round currently being polled
/// (carried across `poll_uplinks → None` returns).
struct Pending {
    round: usize,
    slots: Vec<Option<(Vec<u8>, f64)>>,
    got: usize,
}

/// Socket master: drives the engine side of a socket fleet (local worker
/// threads or external `dore-worker` processes) with nonblocking reads.
/// Bit-identical iterates to every other transport, at every pipeline
/// depth, on a healthy fleet; see the module docs for the crash/reconnect
/// semantics and the two deployment modes.
pub struct TcpTransport {
    /// Master-side connections, one slot per worker (`None` = lost).
    conns: Vec<Option<Conn>>,
    /// Kept open for the whole run so lost workers can re-register.
    listener: Option<TcpListener>,
    addr: Option<SocketAddr>,
    /// External fleet ([`TcpTransport::bind`]): workers are real processes
    /// registering over the network; no local threads are spawned.
    external: bool,
    handles: Vec<JoinHandle<anyhow::Result<Option<u64>>>>,
    window: RoundWindow,
    /// Master-side replay cache: each worker's last fresh encoded uplink,
    /// kept only under [`StalePolicy::ReuseLast`]. A lost worker's entry
    /// is discarded — its replacement starts with an empty mirror too, so
    /// the two sides stay consistent.
    byte_cache: Vec<Option<Vec<u8>>>,
    /// The hello every registering worker must match (version skew is
    /// caught even earlier, by the frame header).
    hello_expect: Option<HelloBody>,
    /// Per-slot Sync payload for fresh registrations: empty = "run from
    /// your own init"; an external resumed run ships the restored state.
    boot_sync: Vec<Vec<u8>>,
    /// `(resume round, master iterate)` for reconnect syncs, refreshed
    /// every round via [`Transport::sync_state`].
    model_sync: Option<(usize, Vec<F>)>,
    pending: Option<Pending>,
    faults: Vec<TransportFault>,
    // lint:allow(wall_clock, reconnect-timeout bookkeeping; never feeds the trajectory)
    lost_since: BTreeMap<usize, Instant>,
    /// Auto-respawn attempts per worker (bounded — a replacement that
    /// keeps dying must not crash-loop forever).
    respawns: BTreeMap<usize, usize>,
    respawn: bool,
    crash_at: BTreeMap<usize, usize>,
    poll_wait: Duration,
    reconnect_timeout: Duration,
    registration_timeout: Duration,
    spec: Option<TrainSpec>,
    problem: Option<Arc<dyn Problem>>,
}

impl Default for TcpTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl TcpTransport {
    /// Local mode: an ephemeral localhost port plus one worker thread per
    /// node (spawned at `start`).
    pub fn new() -> Self {
        Self {
            conns: Vec::new(),
            listener: None,
            addr: None,
            external: false,
            handles: Vec::new(),
            window: RoundWindow::default(),
            byte_cache: Vec::new(),
            hello_expect: None,
            boot_sync: Vec::new(),
            model_sync: None,
            pending: None,
            faults: Vec::new(),
            lost_since: BTreeMap::new(),
            respawns: BTreeMap::new(),
            respawn: false,
            crash_at: BTreeMap::new(),
            poll_wait: Duration::from_millis(10),
            reconnect_timeout: Duration::from_secs(30),
            registration_timeout: Duration::from_secs(60),
            spec: None,
            problem: None,
        }
    }

    /// External mode: bind `addr` (e.g. `"0.0.0.0:7000"`) eagerly and
    /// serve a fleet of `dore-worker` *processes*. No local worker
    /// threads are spawned; `start` waits for `n` registrations, up to
    /// [`TcpTransport::registration_timeout`].
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding master listener on {addr}"))?;
        let mut t = Self::new();
        t.addr = Some(listener.local_addr()?);
        t.listener = Some(listener);
        t.external = true;
        Ok(t)
    }

    /// The bound listener address (useful with a `:0` bind).
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.addr
    }

    /// Auto-spawn a fresh local worker thread for a lost connection (it
    /// re-registers through the same reconnect handshake an external
    /// replacement process would use). Off by default: without it a
    /// persistent loss fails the run after
    /// [`TcpTransport::reconnect_timeout`]. Local mode only — an external
    /// fleet restarts its own `dore-worker` processes.
    pub fn respawn_lost(mut self, yes: bool) -> Self {
        self.respawn = yes;
        self
    }

    /// Chaos knob: worker `worker`'s thread vanishes (dropping its
    /// socket) just before computing round `round` — the in-tree stand-in
    /// for killing a worker process mid-run (the `dore-worker` binary has
    /// `--crash-at` for the real thing).
    pub fn crash_worker(mut self, worker: usize, round: usize) -> Self {
        self.crash_at.insert(worker, round);
        self
    }

    /// How long a worker may stay lost before the run fails loudly
    /// (default 30 s).
    pub fn reconnect_timeout(mut self, timeout: Duration) -> Self {
        self.reconnect_timeout = timeout;
        self
    }

    /// How long `start` waits between registrations before giving up on
    /// the missing workers (default 60 s).
    pub fn registration_timeout(mut self, timeout: Duration) -> Self {
        self.registration_timeout = timeout;
        self
    }

    /// Per-call `poll_uplinks` deadline before it reports "not ready yet"
    /// (`None`) back to the engine (default 10 ms).
    pub fn poll_wait(mut self, wait: Duration) -> Self {
        self.poll_wait = wait;
        self
    }

    fn depth(&self) -> usize {
        self.spec.as_ref().map_or(1, |s| s.pipeline_depth.max(1))
    }

    /// Read and validate a registration hello (fresh or reconnect) off a
    /// just-accepted socket. A mismatch gets a Drain reply naming both
    /// sides before the error — the rejected worker prints something
    /// actionable instead of a dead socket.
    fn read_hello(&self, s: &mut TcpStream) -> anyhow::Result<(usize, FrameKind)> {
        // brief blocking handshake (the connector writes its hello first;
        // sockets accepted from a nonblocking listener may inherit the
        // flag, so set both explicitly)
        s.set_nonblocking(false)?;
        s.set_read_timeout(Some(Duration::from_secs(5)))?;
        let hello = read_frame(s)?;
        anyhow::ensure!(
            matches!(hello.kind, FrameKind::Hello | FrameKind::Reconnect),
            "expected a hello/reconnect frame on a registering socket, got {:?}",
            hello.kind
        );
        let theirs = HelloBody::decode(&hello.payload)?;
        let mine = self.hello_expect.expect("transport started");
        if theirs != mine {
            let text = format!(
                "registration mismatch: master expects dim {} / {} workers / spec \
                 fingerprint {:016x}, worker {} announced dim {} / {} workers / \
                 fingerprint {:016x} — launch every dore-worker with the same problem \
                 and training flags as the master",
                mine.dim,
                mine.n_workers,
                mine.fingerprint,
                hello.worker,
                theirs.dim,
                theirs.n_workers,
                theirs.fingerprint,
            );
            let _ = write_frame(
                s,
                &Frame {
                    kind: FrameKind::Drain,
                    round: 0,
                    worker: hello.worker,
                    residual: 0.0,
                    payload: text.clone().into_bytes(),
                },
            );
            anyhow::bail!("{text}");
        }
        let id = hello.worker as usize;
        anyhow::ensure!(
            id < mine.n_workers as usize,
            "hello from unknown worker slot {id} (fleet of {})",
            mine.n_workers
        );
        Ok((id, hello.kind))
    }

    /// Accept `n` fresh registrations, mapping sockets to worker slots via
    /// their hellos. Nonblocking accepts with a count-based idle deadline:
    /// an external fleet may take a while to launch, and the error names
    /// what is still missing.
    fn accept_registrations(&mut self, n: usize, start_round: usize) -> anyhow::Result<()> {
        let depth = self.depth();
        let mut conns: Vec<Option<Conn>> = (0..n).map(|_| None).collect();
        let mut got = 0usize;
        let max_idle_ticks = (self.registration_timeout.as_millis() as usize / 10).max(1);
        let mut idle = 0usize;
        while got < n {
            let accepted = self
                .listener
                .as_ref()
                .expect("listener bound before registration")
                .accept();
            match accepted {
                Ok((mut s, _)) => {
                    idle = 0;
                    s.set_nodelay(true)?;
                    let (id, kind) = self.read_hello(&mut s)?;
                    anyhow::ensure!(
                        kind == FrameKind::Hello,
                        "worker {id} sent a reconnect hello during fresh registration"
                    );
                    anyhow::ensure!(conns[id].is_none(), "duplicate hello for worker slot {id}");
                    write_frame(
                        &mut s,
                        &Frame {
                            kind: FrameKind::Sync,
                            round: start_round as u32,
                            worker: id as u32,
                            residual: 0.0,
                            payload: self.boot_sync[id].clone(),
                        },
                    )?;
                    s.set_read_timeout(None)?;
                    s.set_nonblocking(true)?;
                    conns[id] = Some(spawn_conn(s, id, depth)?);
                    got += 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    idle += 1;
                    if idle >= max_idle_ticks {
                        let missing: Vec<String> = conns
                            .iter()
                            .enumerate()
                            .filter(|(_, c)| c.is_none())
                            .map(|(i, _)| i.to_string())
                            .collect();
                        anyhow::bail!(
                            "registration timed out: {got} of {n} workers registered within \
                             {:?} (missing slots: {}) — launch the remaining dore-worker \
                             processes (--connect <master> --slot <i>) or raise \
                             TcpTransport::registration_timeout",
                            self.registration_timeout,
                            missing.join(", ")
                        );
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        self.conns = conns;
        Ok(())
    }

    /// Nonblockingly accept and admit any waiting reconnect hellos. A
    /// botched handshake (stray connector, garbage or absent hello, a
    /// peer that died mid-exchange) drops that socket only — it must
    /// never take the training run down with it.
    fn admit_reconnects(&mut self) -> anyhow::Result<()> {
        let mut fresh: Vec<TcpStream> = Vec::new();
        if let Some(listener) = &self.listener {
            loop {
                match listener.accept() {
                    Ok((s, _)) => fresh.push(s),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) => return Err(e.into()),
                }
            }
        }
        for s in fresh {
            // the socket is dropped on a failed handshake; the run goes on
            let _ = self.admit(s);
        }
        Ok(())
    }

    /// The reconnect/re-register handshake: validate the hello, reply
    /// with the resume round + current model, wire up a fresh writer.
    fn admit(&mut self, mut s: TcpStream) -> anyhow::Result<()> {
        s.set_nodelay(true)?;
        let (id, kind) = self.read_hello(&mut s)?;
        anyhow::ensure!(
            kind == FrameKind::Reconnect,
            "unexpected {kind:?} hello on a mid-run socket (fresh registration is over)"
        );
        if let Some(old) = self.conns[id].take() {
            // the re-registration supersedes a connection the master still
            // believed live: an unselected worker's EOF can sit unread for
            // a round or more, and a restarted worker may beat the master
            // to noticing. Retire the old socket and admit the new one.
            close_conn(old);
            self.byte_cache[id] = None;
            self.faults.push(TransportFault { worker: id, rejoined: false });
        }
        let (resume, model) = self
            .model_sync
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no sync state available for a reconnecting worker"))?;
        // a rejoiner is a fresh node: model replayed, residual state zeroed
        let body = SyncBody { model: model.clone(), aux: Vec::new() };
        write_frame(
            &mut s,
            &Frame {
                kind: FrameKind::Sync,
                round: *resume as u32,
                worker: id as u32,
                residual: 0.0,
                payload: body.encode(),
            },
        )?;
        s.set_read_timeout(None)?;
        s.set_nonblocking(true)?;
        self.conns[id] = Some(spawn_conn(s, id, self.depth())?);
        self.lost_since.remove(&id);
        self.faults.push(TransportFault { worker: id, rejoined: true });
        Ok(())
    }

    /// Record a dead connection: discard its replay cache, report the
    /// fault, optionally spawn a local replacement.
    #[allow(clippy::disallowed_methods)] // wall-clock: reconnect-timeout bookkeeping only
    fn mark_lost(&mut self, id: usize) -> anyhow::Result<()> {
        if let Some(conn) = self.conns[id].take() {
            close_conn(conn);
        }
        self.byte_cache[id] = None;
        // lint:allow(wall_clock, reconnect-timeout start mark; never feeds the trajectory)
        self.lost_since.insert(id, Instant::now());
        self.faults.push(TransportFault { worker: id, rejoined: false });
        if self.respawn {
            self.spawn_replacement(id)?;
        }
        Ok(())
    }

    /// Spawn a fresh local worker thread that rejoins as `id`. The node
    /// is rebuilt through the registry — by the resolved algorithm name
    /// the session stamped on the spec ([`TrainSpec::algo_name`], which
    /// covers runtime-registered schemes) or by `spec.algo` — with zeroed
    /// residual state; the sync handshake replays the model. A worker
    /// that keeps dying (e.g. its `import_state` is unsupported) is given
    /// up on after a few attempts instead of crash-looping forever.
    fn spawn_replacement(&mut self, id: usize) -> anyhow::Result<()> {
        const MAX_RESPAWNS_PER_WORKER: usize = 5;
        let tries = self.respawns.entry(id).or_insert(0);
        *tries += 1;
        anyhow::ensure!(
            *tries <= MAX_RESPAWNS_PER_WORKER,
            "worker {id} was lost {tries} times; giving up on auto-respawn (does the \
             algorithm support WorkerNode::import_state?)"
        );
        let spec = self.spec.clone().expect("transport started");
        let problem = self.problem.clone().expect("transport started");
        let addr = self.addr.expect("transport started");
        let n = self.conns.len();
        // cheap registry rebuild; the n − 1 unused siblings are dropped
        let x0 = problem.init();
        let (mut fleet, _master) = match &spec.algo_name {
            Some(name) => registry::build_by_name(name, n, &x0, &spec.hp)?,
            None => registry::build_algorithm(spec.algo, n, &x0, &spec.hp)?,
        };
        let node = fleet.swap_remove(id);
        let boot = WorkerBoot { id, n, addr, problem, spec, crash_at: None };
        self.handles.push(
            std::thread::Builder::new()
                .name(format!("dore-tcp-rejoin-{id}"))
                .spawn(move || tcp_worker_main(boot, node, true))?,
        );
        Ok(())
    }

    /// External-fleet teardown: flush each connection's downlink writer,
    /// then blockingly read the worker's drain frame (discarding any
    /// stale speculative uplinks in front of it) and check its digest.
    fn drain_external(&mut self, expect: Option<u64>) -> anyhow::Result<()> {
        for i in 0..self.conns.len() {
            let Some(mut conn) = self.conns[i].take() else { continue };
            conn.writer_tx = None;
            if let Some(h) = conn.writer.take() {
                let _ = h.join();
            }
            conn.sock.set_nonblocking(false)?;
            conn.sock.set_read_timeout(Some(Duration::from_secs(30)))?;
            let digest = loop {
                match read_frame_buffered(&mut conn) {
                    Ok(f) if f.kind == FrameKind::Drain => break parse_drain_digest(&f.payload)?,
                    // stale speculative uplinks ahead of the drain
                    Ok(f) if f.kind == FrameKind::Uplink => continue,
                    Ok(f) => anyhow::bail!(
                        "unexpected {:?} frame while draining worker {i}",
                        f.kind
                    ),
                    Err(e) => {
                        anyhow::bail!("worker {i} never sent its drain digest: {e}")
                    }
                }
            };
            if let Some(e) = expect {
                anyhow::ensure!(
                    digest == e,
                    "worker {i}'s final model desynced from the master's \
                     (digest {digest:016x}, master {e:016x})"
                );
            }
        }
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn start(
        &mut self,
        workers: Vec<Box<dyn WorkerNode>>,
        shared_problem: Option<Arc<dyn Problem>>,
        spec: &TrainSpec,
    ) -> anyhow::Result<()> {
        let problem = shared_problem.ok_or_else(|| {
            anyhow::anyhow!(
                "the tcp transport runs workers on their own threads and needs a shared \
                 problem: build the session with Session::shared(Arc<dyn Problem>)"
            )
        })?;
        anyhow::ensure!(
            !(self.external && self.respawn),
            "respawn_lost spawns local threads; an external fleet restarts its own \
             dore-worker processes instead"
        );
        let n = workers.len();
        let dim = problem.dim();
        self.byte_cache = (0..n).map(|_| None).collect();
        self.window.reset(spec.start_round);
        self.pending = None;
        self.faults.clear();
        self.lost_since.clear();
        self.respawns.clear();
        self.model_sync = None;
        self.spec = Some(spec.clone());
        self.problem = Some(problem.clone());
        self.hello_expect = Some(HelloBody {
            dim: dim as u32,
            n_workers: n as u32,
            fingerprint: spec_fingerprint(spec, dim, n),
        });

        let listener = match self.listener.take() {
            Some(l) => l, // external: bound eagerly by `bind`
            None => TcpListener::bind("127.0.0.1:0")?,
        };
        let addr = listener.local_addr()?;
        self.addr = Some(addr);
        // registrations and reconnects arrive on the same listener,
        // accepted nonblockingly with a count-based deadline
        listener.set_nonblocking(true)?;
        self.listener = Some(listener);

        if self.external {
            // real processes own the nodes; ship the restored state on a
            // resumed run, otherwise an empty Sync payload means "run from
            // your own deterministic init"
            self.boot_sync = if spec.start_round > 0 {
                workers
                    .iter()
                    .map(|w| {
                        SyncBody { model: w.model().to_vec(), aux: w.export_state() }.encode()
                    })
                    .collect()
            } else {
                (0..n).map(|_| Vec::new()).collect()
            };
        } else {
            self.boot_sync = (0..n).map(|_| Vec::new()).collect();
            for (id, node) in workers.into_iter().enumerate() {
                let boot = WorkerBoot {
                    id,
                    n,
                    addr,
                    problem: problem.clone(),
                    spec: spec.clone(),
                    crash_at: self.crash_at.get(&id).copied(),
                };
                self.handles.push(
                    std::thread::Builder::new()
                        .name(format!("dore-tcp-{id}"))
                        .spawn(move || tcp_worker_main(boot, node, false))?,
                );
            }
        }
        self.accept_registrations(n, spec.start_round)
    }

    fn begin_round(
        &mut self,
        round: usize,
        ctx: RoundCtx<'_>,
        inject: Vec<UplinkFrame>,
    ) -> anyhow::Result<()> {
        self.window.begin(round, self.conns.len(), ctx.mask, ctx.spec.stale, inject)
    }

    #[allow(clippy::disallowed_methods)] // wall-clock: nonblocking-poll deadlines only
    fn poll_uplinks(
        &mut self,
        round: usize,
        ctx: RoundCtx<'_>,
    ) -> anyhow::Result<Option<Vec<UplinkFrame>>> {
        self.window.ensure_open(round)?;
        let n = self.conns.len();
        let mask = ctx.mask;
        anyhow::ensure!(mask.len() == n, "round mask covers {} of {n} workers", mask.len());
        let fastest_k = match &ctx.spec.participation {
            Participation::Fastest { k } => Some(*k),
            _ => None,
        };
        let mut pending = match self.pending.take() {
            Some(p) if p.round == round => p,
            _ => Pending { round, slots: (0..n).map(|_| None).collect(), got: 0 },
        };
        // speed-aware mode closes the barrier after the first k arrivals;
        // derived masks await exactly the selected subset
        let expected = fastest_k.unwrap_or_else(|| mask.iter().filter(|&&m| m).count());
        // lint:allow(wall_clock, nonblocking-poll deadline; bounds the wait, never the result)
        let deadline = Instant::now() + self.poll_wait;
        // Workers emit uplinks in round order, so the next *fresh* frame
        // assembled from a socket is exactly round `round`; under fastest,
        // losers' unconsumed speculative frames of older rounds are
        // discarded first.
        while pending.got < expected {
            self.admit_reconnects()?;
            let mut progress = false;
            'conns: for i in 0..n {
                if !mask[i] || pending.slots[i].is_some() {
                    continue;
                }
                loop {
                    let outcome = match self.conns[i].as_mut() {
                        Some(conn) => conn_try_read(conn)?,
                        None => {
                            // lost: the round stalls until a replacement
                            // re-registers; fail loudly if none ever does
                            if let Some(t0) = self.lost_since.get(&i) {
                                anyhow::ensure!(
                                    t0.elapsed() < self.reconnect_timeout,
                                    "worker {i} was lost at round {round} and nothing \
                                     re-registered within {:?} (enable \
                                     TcpTransport::respawn_lost or restart the worker)",
                                    self.reconnect_timeout
                                );
                            }
                            continue 'conns;
                        }
                    };
                    match outcome {
                        SockRead::Frame(f) => {
                            if fastest_k.is_some()
                                && f.kind == FrameKind::Uplink
                                && (f.round as usize) < round
                            {
                                // a dropped speculative uplink from an
                                // earlier round: discard and re-read
                                continue;
                            }
                            anyhow::ensure!(
                                f.kind == FrameKind::Uplink
                                    && f.round == round as u32
                                    && f.worker as usize == i,
                                "protocol skew on worker {i} at round {round}"
                            );
                            pending.slots[i] = Some((f.payload, f.residual));
                            pending.got += 1;
                            progress = true;
                            if pending.got >= expected {
                                break 'conns;
                            }
                            continue 'conns;
                        }
                        SockRead::WouldBlock => continue 'conns,
                        SockRead::Lost => {
                            self.mark_lost(i)?;
                            continue 'conns;
                        }
                    }
                }
            }
            if pending.got >= expected {
                break;
            }
            // lint:allow(wall_clock, nonblocking-poll deadline check; engine re-polls)
            if Instant::now() >= deadline {
                // nonblocking contract: not resolvable yet — park the
                // partial assembly, the engine yields and re-polls
                self.pending = Some(pending);
                return Ok(None);
            }
            if !progress {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
        let reuse = ctx.spec.stale == StalePolicy::ReuseLast;
        let mut injected = self.window.take_injected(round, n);
        let frames = pending
            .slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| match s {
                Some((payload, residual_norm)) => {
                    if reuse {
                        self.byte_cache[i] = Some(payload.clone());
                    }
                    UplinkFrame {
                        worker: i,
                        round,
                        payload: Some(WirePayload::Encoded(payload)),
                        residual_norm,
                        compute_seconds: 0.0,
                    }
                }
                // absentee: injected stand-in, replay cache, or empty
                None => absent_slot_frame(&mut injected, &self.byte_cache, reuse, round, i),
            })
            .collect();
        Ok(Some(frames))
    }

    fn push_downlink(
        &mut self,
        round: usize,
        down: &Compressed,
        ctx: RoundCtx<'_>,
    ) -> anyhow::Result<u64> {
        let bytes = codec::encode_with(down, ctx.spec.wire_codec);
        let bits = bytes.len() as u64 * 8;
        // under fastest the broadcast carries the realized mask (the
        // session passes it as ctx.mask at push time) so every worker
        // learns whether its speculative uplink stood; the prefix is
        // per-frame overhead, accounted like the frame header
        let wire = if ctx.spec.participation.is_fastest() {
            encode_masked_downlink(ctx.mask, &bytes)
        } else {
            bytes
        };
        // hand off to the per-worker writer threads: the master's loop
        // stays free to keep reading uplinks, which is what prevents the
        // depth ≥ 2 write/write deadlock on large payloads. A lost
        // worker's broadcasts are skipped — the reconnect sync replays
        // the model it missed.
        let mut dead: Vec<usize> = Vec::new();
        for (i, c) in self.conns.iter().enumerate() {
            let Some(conn) = c else { continue };
            let Some(tx) = &conn.writer_tx else { continue };
            if tx.send(DownlinkMsg { round, bytes: wire.clone() }).is_err() {
                // the writer exited on a broken socket between polls
                dead.push(i);
            }
        }
        for i in dead {
            self.mark_lost(i)?;
        }
        Ok(bits)
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        // stop admitting reconnects first: a straggling replacement
        // blocked on its sync read sees the connection close and exits
        // cleanly (returning None) instead of hanging the join below
        self.listener = None;
        self.addr = None;
        // the cheap invariant that catches any fleet desync a fault path
        // could introduce: every surviving worker reports a digest of its
        // final model, checked against the master's iterate
        let expect = self.model_sync.take().map(|(_, m)| digest_f32(&m));
        if self.external {
            self.drain_external(expect)?;
        } else {
            // dropping the senders lets each writer flush its queued
            // downlinks and exit; join writers before workers so the tail
            // broadcasts the workers are draining actually reach them
            for conn in self.conns.iter_mut().filter_map(|c| c.take()) {
                close_conn(conn);
            }
            for h in self.handles.drain(..) {
                let digest =
                    h.join().map_err(|_| anyhow::anyhow!("tcp worker panicked"))??;
                if let (Some(d), Some(e)) = (digest, expect) {
                    anyhow::ensure!(
                        d == e,
                        "a worker's final model desynced from the master's (digest mismatch)"
                    );
                }
            }
        }
        self.conns.clear();
        self.pending = None;
        Ok(())
    }

    fn sync_state(&mut self, next_round: usize, model: &[F]) {
        // reuse the buffer: this runs every round, a reconnect almost never
        match &mut self.model_sync {
            Some((r, buf)) if buf.len() == model.len() => {
                *r = next_round;
                buf.copy_from_slice(model);
            }
            slot => *slot = Some((next_round, model.to_vec())),
        }
    }

    fn drain_faults(&mut self) -> Vec<TransportFault> {
        std::mem::take(&mut self.faults)
    }

    fn supports_fastest(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;
    use crate::engine::{Session, Threaded};

    #[test]
    fn tcp_matches_inproc_and_threaded_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Diana] {
            let spec = TrainSpec { algo, iters: 20, eval_every: 5, ..Default::default() };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec.clone())
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            let c = Session::shared(p.clone())
                .spec(spec)
                .transport(Threaded::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "{}", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
            assert_eq!(b.loss, c.loss);
            assert_eq!(a.final_model_digest, b.final_model_digest);
        }
    }

    #[test]
    fn tcp_pipelined_depths_match_inproc_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 2, 0.1, 4));
        for depth in [2usize, 3] {
            let spec = TrainSpec {
                algo: AlgorithmKind::Dore,
                iters: 15,
                eval_every: 5,
                pipeline_depth: depth,
                ..Default::default()
            };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec)
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "depth {depth}: tcp diverged from inproc");
            assert_eq!(a.dist_to_opt, b.dist_to_opt, "depth {depth}");
        }
    }

    #[test]
    fn fastest_over_tcp_records_k_sized_masks_and_replays_on_inproc() {
        use crate::engine::participation::MaskSchedule;
        let p = Arc::new(linreg_problem(50, 12, 4, 0.1, 9));
        let spec = TrainSpec {
            algo: AlgorithmKind::Dore,
            iters: 8,
            eval_every: 2,
            participation: Participation::Fastest { k: 3 },
            ..Default::default()
        };
        let live = Session::shared(p.clone())
            .spec(spec.clone())
            .transport(TcpTransport::new())
            .run()
            .unwrap();
        assert_eq!(live.realized_masks.len(), 8);
        for (r, m) in live.realized_masks.iter().enumerate() {
            assert_eq!(m.len(), 4, "round {r}");
            assert_eq!(m.iter().filter(|&&b| b).count(), 3, "round {r}: {m:?}");
        }
        // replaying the recorded masks on the zero-copy reference transport
        // reproduces the run bit-for-bit — arrival order became data
        let sched = MaskSchedule { masks: live.realized_masks.clone() };
        let replay_spec = TrainSpec {
            participation: Participation::Recorded(Arc::new(sched)),
            ..spec
        };
        let replay = Session::new(p.as_ref()).spec(replay_spec).run().unwrap();
        assert_eq!(live.loss, replay.loss);
        assert_eq!(live.final_model_digest, replay.final_model_digest);
        assert_eq!(live.realized_masks, replay.realized_masks);
    }
}
