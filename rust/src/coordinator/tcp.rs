//! TCP transport for the round engine: the same master/worker state
//! machines and the same [`crate::engine::Session`] loop as every other
//! transport, but over real sockets with a length-prefixed frame protocol —
//! the deployment shape the paper's testbed used (PS + workers on
//! Ethernet).
//!
//! Frame layout (little-endian):
//! ```text
//! [u32 payload_len][u8 kind][u32 round][u32 worker][f64 residual][payload]
//! ```
//! `kind` is 0 = uplink, 1 = downlink; `payload` is a
//! [`crate::compression::codec`] buffer. Byte accounting counts payload
//! bytes only (header bytes are fixed per message and reported separately),
//! keeping the numbers comparable with the other transports.
//!
//! Pipelining rides the sockets naturally: each worker writes its
//! round-`k` uplink after reading the round-`k − depth` downlink, so up to
//! `depth` uplinks are on the wire per link while the master reduces older
//! rounds. Because a worker emits its uplink frames in round order, the
//! next unread uplink frame on a socket is always the oldest round the
//! master still needs — per-socket sequential reads need no reordering
//! buffer. Downlinks are written by one dedicated writer thread per worker
//! (fed from an unbounded channel), so the master's read loop never blocks
//! on a full send buffer: with `depth ≥ 2` a worker can be mid-write of
//! uplink `t + 1` while the master broadcasts round `t`, and payloads
//! larger than the kernel socket buffers would otherwise deadlock the two
//! blocking writes against each other.

use crate::algorithms::WorkerNode;
use crate::compression::{codec, Compressed};
use crate::engine::protocol::DownlinkMsg;
use crate::engine::transport::{absent_slot_frame, RoundWindow, WorkerRoundDriver};
use crate::engine::{RoundCtx, StalePolicy, TrainSpec, Transport, UplinkFrame, WirePayload};
use crate::models::Problem;
use crate::F;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

const KIND_UPLINK: u8 = 0;
const KIND_DOWNLINK: u8 = 1;
/// Fixed header bytes per frame (len + kind + round + worker + residual).
pub const HEADER_BYTES: u64 = 4 + 1 + 4 + 4 + 8;

struct Frame {
    kind: u8,
    round: u32,
    worker: u32,
    residual: f64,
    payload: Vec<u8>,
}

fn write_frame(s: &mut TcpStream, f: &Frame) -> anyhow::Result<()> {
    let mut head = [0u8; HEADER_BYTES as usize];
    head[0..4].copy_from_slice(&(f.payload.len() as u32).to_le_bytes());
    head[4] = f.kind;
    head[5..9].copy_from_slice(&f.round.to_le_bytes());
    head[9..13].copy_from_slice(&f.worker.to_le_bytes());
    head[13..21].copy_from_slice(&f.residual.to_le_bytes());
    s.write_all(&head)?;
    s.write_all(&f.payload)?;
    Ok(())
}

fn read_frame(s: &mut TcpStream) -> anyhow::Result<Frame> {
    let mut head = [0u8; HEADER_BYTES as usize];
    s.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
    anyhow::ensure!(len <= (1 << 30), "absurd frame length {len}");
    let mut payload = vec![0u8; len];
    s.read_exact(&mut payload)?;
    Ok(Frame {
        kind: head[4],
        round: u32::from_le_bytes(head[5..9].try_into().unwrap()),
        worker: u32::from_le_bytes(head[9..13].try_into().unwrap()),
        residual: f64::from_le_bytes(head[13..21].try_into().unwrap()),
        payload,
    })
}

fn tcp_worker_loop(
    id: usize,
    n: usize,
    mut node: Box<dyn WorkerNode>,
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
    addr: SocketAddr,
) -> anyhow::Result<()> {
    let mut sock = TcpStream::connect(addr)?;
    sock.set_nodelay(true)?;
    // identify ourselves once
    write_frame(
        &mut sock,
        &Frame {
            kind: KIND_UPLINK,
            round: u32::MAX,
            worker: id as u32,
            residual: 0.0,
            payload: vec![],
        },
    )?;
    fn read_apply(
        sock: &mut TcpStream,
        node: &mut dyn WorkerNode,
        round: usize,
    ) -> anyhow::Result<()> {
        let down = read_frame(sock)?;
        anyhow::ensure!(down.kind == KIND_DOWNLINK, "bad frame kind");
        anyhow::ensure!(down.round == round as u32, "round skew");
        node.apply_downlink(round, &codec::decode(&down.payload)?);
        Ok(())
    }
    let depth = spec.pipeline_depth.max(1);
    let mut grad = vec![0.0 as F; problem.dim()];
    let mut driver = WorkerRoundDriver::new(&spec, n);
    for k in 0..spec.iters {
        // the round-k uplink is computed against the model with downlinks
        // through k − depth applied — the pipelined staleness contract
        if k >= depth {
            read_apply(&mut sock, node.as_mut(), k - depth)?;
        }
        if let Some((payload, residual)) =
            driver.round(node.as_mut(), problem.as_ref(), &spec, k, id, &mut grad)
        {
            write_frame(
                &mut sock,
                &Frame { kind: KIND_UPLINK, round: k as u32, worker: id as u32, residual, payload },
            )?;
        }
    }
    // drain the tail so every downlink is applied and the final model
    // copies agree with the master's
    for t in spec.iters.saturating_sub(depth)..spec.iters {
        read_apply(&mut sock, node.as_mut(), t)?;
    }
    Ok(())
}

/// The per-worker downlink writer: drains queued broadcasts onto its write
/// half of the socket so the master's read loop never blocks on a full
/// send buffer (the depth ≥ 2 deadlock guard — see the module docs). The
/// feeding channel is bounded at the pipeline depth: a worker that keeps
/// consuming downlinks never backs the master up (selected workers are at
/// most `depth` broadcasts behind by the pacing contract), while a wedged
/// fleet exerts backpressure instead of queueing the whole run's
/// broadcasts in memory. Exits when the master drops its sender;
/// remaining queued frames are flushed first.
fn tcp_downlink_writer(mut sock: TcpStream, rx: Receiver<DownlinkMsg>) -> anyhow::Result<()> {
    while let Ok(m) = rx.recv() {
        write_frame(
            &mut sock,
            &Frame {
                kind: KIND_DOWNLINK,
                round: m.round as u32,
                worker: 0,
                residual: 0.0,
                payload: m.bytes,
            },
        )?;
    }
    Ok(())
}

/// Socket transport: binds an ephemeral localhost port, runs one OS thread
/// per worker (each with its own socket) and drives the master side from
/// the engine loop. Bit-identical iterates to every other transport, at
/// every pipeline depth.
#[derive(Default)]
pub struct TcpTransport {
    /// Master-side read halves, one per worker.
    socks: Vec<TcpStream>,
    /// Queues feeding the per-worker downlink writer threads (bounded at
    /// the pipeline depth).
    writer_txs: Vec<SyncSender<DownlinkMsg>>,
    writer_handles: Vec<JoinHandle<anyhow::Result<()>>>,
    handles: Vec<JoinHandle<anyhow::Result<()>>>,
    window: RoundWindow,
    /// Master-side replay cache: each worker's last fresh encoded uplink,
    /// kept only under [`StalePolicy::ReuseLast`].
    byte_cache: Vec<Option<Vec<u8>>>,
}

impl TcpTransport {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn start(
        &mut self,
        workers: Vec<Box<dyn WorkerNode>>,
        shared_problem: Option<Arc<dyn Problem>>,
        spec: &TrainSpec,
    ) -> anyhow::Result<()> {
        let problem = shared_problem.ok_or_else(|| {
            anyhow::anyhow!(
                "the tcp transport runs workers on their own threads and needs a shared \
                 problem: build the session with Session::shared(Arc<dyn Problem>)"
            )
        })?;
        let n = workers.len();
        self.byte_cache = (0..n).map(|_| None).collect();
        self.window.reset();
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;

        for (id, node) in workers.into_iter().enumerate() {
            let p = problem.clone();
            let s = spec.clone();
            self.handles.push(
                std::thread::Builder::new()
                    .name(format!("dore-tcp-{id}"))
                    .spawn(move || tcp_worker_loop(id, n, node, p, s, addr))?,
            );
        }

        // accept n connections, map them to worker ids via hello frames
        let mut socks: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (mut s, _) = listener.accept()?;
            s.set_nodelay(true)?;
            let hello = read_frame(&mut s)?;
            anyhow::ensure!(hello.round == u32::MAX, "expected hello frame");
            let id = hello.worker as usize;
            anyhow::ensure!(id < n && socks[id].is_none(), "bad hello worker id");
            socks[id] = Some(s);
        }
        self.socks = socks.into_iter().map(|s| s.expect("accepted every id")).collect();
        // one downlink writer per worker, on a cloned write half
        let depth = spec.pipeline_depth.max(1);
        for (id, s) in self.socks.iter().enumerate() {
            let (tx, rx) = std::sync::mpsc::sync_channel::<DownlinkMsg>(depth);
            let w = s.try_clone()?;
            self.writer_txs.push(tx);
            self.writer_handles.push(
                std::thread::Builder::new()
                    .name(format!("dore-tcp-down-{id}"))
                    .spawn(move || tcp_downlink_writer(w, rx))?,
            );
        }
        Ok(())
    }

    fn begin_round(
        &mut self,
        round: usize,
        ctx: RoundCtx<'_>,
        inject: Vec<UplinkFrame>,
    ) -> anyhow::Result<()> {
        self.window.begin(round, self.socks.len(), ctx.mask, ctx.spec.stale, inject)
    }

    fn poll_uplinks(
        &mut self,
        round: usize,
        ctx: RoundCtx<'_>,
    ) -> anyhow::Result<Option<Vec<UplinkFrame>>> {
        self.window.ensure_open(round)?;
        let n = self.socks.len();
        let mask = ctx.mask;
        anyhow::ensure!(mask.len() == n, "round mask covers {} of {n} workers", mask.len());
        let reuse = ctx.spec.stale == StalePolicy::ReuseLast;
        let mut injected = self.window.take_injected(round, n);
        let mut frames = Vec::with_capacity(n);
        for (i, s) in self.socks.iter_mut().enumerate() {
            // only selected workers transmit this round; absentees' slots
            // are filled by an injected stand-in, the replay cache
            // (reuse-last), or left empty
            if !mask[i] {
                frames.push(absent_slot_frame(&mut injected, &self.byte_cache, reuse, round, i));
                continue;
            }
            // workers emit uplinks in round order, so the next unread
            // uplink frame on this socket is exactly round `round`
            let f = read_frame(s)?;
            anyhow::ensure!(
                f.kind == KIND_UPLINK && f.round == round as u32 && f.worker as usize == i,
                "protocol skew on worker {i} at round {round}"
            );
            if reuse {
                self.byte_cache[i] = Some(f.payload.clone());
            }
            frames.push(UplinkFrame {
                worker: i,
                round,
                payload: Some(WirePayload::Encoded(f.payload)),
                residual_norm: f.residual,
                compute_seconds: 0.0,
            });
        }
        Ok(Some(frames))
    }

    fn push_downlink(
        &mut self,
        round: usize,
        down: &Compressed,
        _ctx: RoundCtx<'_>,
    ) -> anyhow::Result<u64> {
        let bytes = codec::encode(down);
        let bits = bytes.len() as u64 * 8;
        // hand off to the per-worker writer threads: the master's loop
        // stays free to keep reading uplinks, which is what prevents the
        // depth ≥ 2 write/write deadlock on large payloads
        for tx in &self.writer_txs {
            tx.send(DownlinkMsg { round, bytes: bytes.clone() })
                .map_err(|_| anyhow::anyhow!("downlink writer hung up"))?;
        }
        Ok(bits)
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        // dropping the senders lets each writer flush its queued downlinks
        // and exit; join writers before workers so the tail broadcasts the
        // workers are draining actually reach them
        self.writer_txs.clear();
        for h in self.writer_handles.drain(..) {
            h.join().map_err(|_| anyhow::anyhow!("tcp downlink writer panicked"))??;
        }
        for h in self.handles.drain(..) {
            h.join().map_err(|_| anyhow::anyhow!("tcp worker panicked"))??;
        }
        self.socks.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;
    use crate::engine::{Session, Threaded};

    #[test]
    fn tcp_matches_inproc_and_threaded_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Diana] {
            let spec = TrainSpec { algo, iters: 20, eval_every: 5, ..Default::default() };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec.clone())
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            let c = Session::shared(p.clone())
                .spec(spec)
                .transport(Threaded::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "{}", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
            assert_eq!(b.loss, c.loss);
        }
    }

    #[test]
    fn tcp_pipelined_depths_match_inproc_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 2, 0.1, 4));
        for depth in [2usize, 3] {
            let spec = TrainSpec {
                algo: AlgorithmKind::Dore,
                iters: 15,
                eval_every: 5,
                pipeline_depth: depth,
                ..Default::default()
            };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec)
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "depth {depth}: tcp diverged from inproc");
            assert_eq!(a.dist_to_opt, b.dist_to_opt, "depth {depth}");
        }
    }

    #[test]
    fn frame_roundtrip() {
        // loopback socket pair via a throwaway listener
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        let f = Frame {
            kind: KIND_DOWNLINK,
            round: 7,
            worker: 3,
            residual: 2.5,
            payload: vec![1, 2, 3, 4, 5],
        };
        write_frame(&mut client, &f).unwrap();
        let g = read_frame(&mut server).unwrap();
        assert_eq!(g.kind, KIND_DOWNLINK);
        assert_eq!(g.round, 7);
        assert_eq!(g.worker, 3);
        assert_eq!(g.residual, 2.5);
        assert_eq!(g.payload, vec![1, 2, 3, 4, 5]);
    }
}
