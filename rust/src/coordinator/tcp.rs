//! TCP transport for the parameter server: the same master/worker state
//! machines as the in-process harness and the channel-based coordinator,
//! but over real sockets with a length-prefixed frame protocol — the
//! deployment shape the paper's testbed used (PS + workers on Ethernet).
//!
//! Frame layout (little-endian):
//! ```text
//! [u32 payload_len][u8 kind][u32 round][u32 worker][f64 residual][payload]
//! ```
//! `kind` is 0 = uplink, 1 = downlink; `payload` is a
//! [`crate::compression::codec`] buffer. Byte accounting counts payload
//! bytes only (header bytes are fixed per message and reported separately),
//! keeping the numbers comparable with the other two drivers.

use crate::algorithms::build;
use crate::compression::{codec, Xoshiro256};
use crate::harness::TrainSpec;
use crate::metrics::{RunMetrics, Stopwatch};
use crate::models::{linalg, Problem};
use crate::F;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

const KIND_UPLINK: u8 = 0;
const KIND_DOWNLINK: u8 = 1;
/// Fixed header bytes per frame (len + kind + round + worker + residual).
pub const HEADER_BYTES: u64 = 4 + 1 + 4 + 4 + 8;

struct Frame {
    kind: u8,
    round: u32,
    worker: u32,
    residual: f64,
    payload: Vec<u8>,
}

fn write_frame(s: &mut TcpStream, f: &Frame) -> anyhow::Result<()> {
    let mut head = [0u8; HEADER_BYTES as usize];
    head[0..4].copy_from_slice(&(f.payload.len() as u32).to_le_bytes());
    head[4] = f.kind;
    head[5..9].copy_from_slice(&f.round.to_le_bytes());
    head[9..13].copy_from_slice(&f.worker.to_le_bytes());
    head[13..21].copy_from_slice(&f.residual.to_le_bytes());
    s.write_all(&head)?;
    s.write_all(&f.payload)?;
    Ok(())
}

fn read_frame(s: &mut TcpStream) -> anyhow::Result<Frame> {
    let mut head = [0u8; HEADER_BYTES as usize];
    s.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
    anyhow::ensure!(len <= (1 << 30), "absurd frame length {len}");
    let mut payload = vec![0u8; len];
    s.read_exact(&mut payload)?;
    Ok(Frame {
        kind: head[4],
        round: u32::from_le_bytes(head[5..9].try_into().unwrap()),
        worker: u32::from_le_bytes(head[9..13].try_into().unwrap()),
        residual: f64::from_le_bytes(head[13..21].try_into().unwrap()),
        payload,
    })
}

/// Run a training job over localhost TCP: binds an ephemeral port, spawns
/// one OS thread per worker (each with its own socket), drives the master
/// on the calling thread. Produces iterates bit-identical to
/// [`super::run_distributed`] and the in-process harness.
pub fn run_distributed_tcp(
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
) -> anyhow::Result<RunMetrics> {
    let n = problem.n_workers();
    let x0 = problem.init();
    let (workers, mut master) = build(spec.algo, n, &x0, &spec.hp)?;

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;

    // worker threads: connect, then run the synchronous round loop
    let mut handles = Vec::with_capacity(n);
    for (id, mut node) in workers.into_iter().enumerate() {
        let problem = problem.clone();
        let spec = spec.clone();
        handles.push(std::thread::Builder::new().name(format!("dore-tcp-{id}")).spawn(
            move || -> anyhow::Result<()> {
                let mut sock = TcpStream::connect(addr)?;
                sock.set_nodelay(true)?;
                // identify ourselves once
                write_frame(
                    &mut sock,
                    &Frame { kind: KIND_UPLINK, round: u32::MAX, worker: id as u32, residual: 0.0, payload: vec![] },
                )?;
                let d = problem.dim();
                let mut grad = vec![0.0 as F; d];
                for k in 0..spec.iters {
                    let mut grad_rng =
                        Xoshiro256::for_site(spec.seed ^ 0x5eed, 1 + id as u64, k as u64);
                    problem.local_grad(id, node.model(), spec.minibatch, &mut grad_rng, &mut grad);
                    let mut qrng = Xoshiro256::for_site(spec.seed, 1 + id as u64, k as u64);
                    let up = node.round(k, &grad, &mut qrng);
                    write_frame(
                        &mut sock,
                        &Frame {
                            kind: KIND_UPLINK,
                            round: k as u32,
                            worker: id as u32,
                            residual: node.last_compressed_norm(),
                            payload: codec::encode(&up),
                        },
                    )?;
                    let down = read_frame(&mut sock)?;
                    anyhow::ensure!(down.kind == KIND_DOWNLINK, "bad frame kind");
                    anyhow::ensure!(down.round == k as u32, "round skew");
                    node.apply_downlink(k, &codec::decode(&down.payload)?);
                }
                Ok(())
            },
        )?);
    }

    // master: accept n connections, map them to worker ids via hello frames
    let mut socks: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let (mut s, _) = listener.accept()?;
        s.set_nodelay(true)?;
        let hello = read_frame(&mut s)?;
        anyhow::ensure!(hello.round == u32::MAX, "expected hello frame");
        let id = hello.worker as usize;
        anyhow::ensure!(id < n && socks[id].is_none(), "bad hello worker id");
        socks[id] = Some(s);
    }
    let mut socks: Vec<TcpStream> = socks.into_iter().map(Option::unwrap).collect();

    let sw = Stopwatch::start();
    let mut metrics = RunMetrics::new(spec.algo.name());
    for k in 0..spec.iters {
        let mut uplinks = Vec::with_capacity(n);
        let mut res_sum = 0.0;
        for s in socks.iter_mut() {
            let f = read_frame(s)?;
            anyhow::ensure!(f.kind == KIND_UPLINK && f.round == k as u32, "protocol skew");
            metrics.uplink_bits += f.payload.len() as u64 * 8;
            res_sum += f.residual;
            uplinks.push(codec::decode(&f.payload)?);
        }
        let mut mrng = Xoshiro256::for_site(spec.seed, 0, k as u64);
        let down = master.round(k, &uplinks, &mut mrng);
        let bytes = codec::encode(&down);
        metrics.downlink_bits += bytes.len() as u64 * 8 * n as u64;
        for s in socks.iter_mut() {
            write_frame(
                s,
                &Frame {
                    kind: KIND_DOWNLINK,
                    round: k as u32,
                    worker: 0,
                    residual: master.last_compressed_norm(),
                    payload: bytes.clone(),
                },
            )?;
        }
        if k % spec.eval_every == 0 || k + 1 == spec.iters {
            let x = master.model();
            metrics.rounds.push(k);
            metrics.loss.push(problem.loss(x));
            if let Some(xs) = problem.optimum() {
                metrics.dist_to_opt.push(linalg::dist2(x, xs));
            }
            if let Some(tl) = problem.test_loss(x) {
                metrics.test_loss.push(tl);
            }
            if let Some(ta) = problem.test_accuracy(x) {
                metrics.test_acc.push(ta);
            }
            metrics.worker_residual_norm.push(res_sum / n as f64);
            metrics.master_residual_norm.push(master.last_compressed_norm());
        }
    }
    metrics.total_rounds = spec.iters;
    metrics.wall_seconds = sw.seconds();
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("tcp worker panicked"))??;
    }
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;
    use crate::harness::run_inproc;

    #[test]
    fn tcp_matches_inproc_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Diana] {
            let spec = TrainSpec { algo, iters: 20, eval_every: 5, ..Default::default() };
            let a = run_inproc(p.as_ref(), &spec);
            let b = run_distributed_tcp(p.clone(), spec).unwrap();
            assert_eq!(a.loss, b.loss, "{}", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
        }
    }

    #[test]
    fn frame_roundtrip() {
        // loopback socket pair via a throwaway listener
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        let f = Frame {
            kind: KIND_DOWNLINK,
            round: 7,
            worker: 3,
            residual: 2.5,
            payload: vec![1, 2, 3, 4, 5],
        };
        write_frame(&mut client, &f).unwrap();
        let g = read_frame(&mut server).unwrap();
        assert_eq!(g.kind, KIND_DOWNLINK);
        assert_eq!(g.round, 7);
        assert_eq!(g.worker, 3);
        assert_eq!(g.residual, 2.5);
        assert_eq!(g.payload, vec![1, 2, 3, 4, 5]);
    }
}
