//! TCP transport for the round engine: the same master/worker state
//! machines and the same [`crate::engine::Session`] loop as every other
//! transport, but over real sockets with a length-prefixed frame protocol —
//! the deployment shape the paper's testbed used (PS + workers on
//! Ethernet).
//!
//! Frame layout (little-endian):
//! ```text
//! [u32 payload_len][u8 kind][u32 round][u32 worker][f64 residual][payload]
//! ```
//! `kind` is 0 = uplink, 1 = downlink, 2 = reconnect hello, 3 = master →
//! rejoiner sync; `payload` is a [`crate::compression::codec`] buffer.
//! Byte accounting counts payload bytes only (header bytes are fixed per
//! message and reported separately), keeping the numbers comparable with
//! the other transports.
//!
//! Pipelining rides the sockets naturally: each worker writes its
//! round-`k` uplink after reading the round-`k − depth` downlink, so up to
//! `depth` uplinks are on the wire per link while the master reduces older
//! rounds. Because a worker emits its uplink frames in round order, the
//! next unread uplink frame on a socket is always the oldest round the
//! master still needs — per-socket sequential reads need no reordering
//! buffer. Downlinks are written by one dedicated writer thread per worker
//! (fed from a depth-bounded channel), so the master's read loop never
//! blocks on a full send buffer: with `depth ≥ 2` a worker can be mid-write
//! of uplink `t + 1` while the master broadcasts round `t`, and payloads
//! larger than the kernel socket buffers would otherwise deadlock the two
//! blocking writes against each other.
//!
//! # Fault tolerance
//!
//! The master side reads **nonblockingly**: each socket has a reassembly
//! buffer, and [`Transport::poll_uplinks`] returns `None` (the engine
//! yields and re-polls) when a round cannot be resolved within the poll
//! deadline instead of parking the run on a dead `read`. A worker whose
//! connection drops (EOF / reset mid-frame) is **lost**: its replay cache
//! is discarded, the loss is reported through [`Transport::drain_faults`],
//! and the round stalls until a replacement **re-registers** — the
//! listener stays open, and a reconnect hello is answered with a sync
//! frame carrying the resume round plus the master's current model (fed
//! each round via [`Transport::sync_state`]). The rejoined worker starts
//! with fresh (zeroed) residual state — the master's `h`/error state
//! carries what the paper's algebra needs, so training proceeds and the
//! fleet's models stay synchronized (verified: at `finish` every worker
//! returns a digest of its final model, checked against the master's) —
//! but a run with a real crash is *not* bit-identical to an uninterrupted
//! one; use [`crate::engine::FaultPlan`] for deterministic failure
//! injection and [`crate::engine::Session::checkpoint_every`] for
//! bit-exact kill/resume. [`TcpTransport::respawn_lost`] auto-spawns a
//! local replacement thread for a lost worker (the chaos-test path);
//! without it, a worker that stays lost past
//! [`TcpTransport::reconnect_timeout`] fails the run with an actionable
//! error rather than hanging forever.

use crate::algorithms::{digest_f32, WorkerNode};
use crate::compression::{codec, Compressed};
use crate::engine::protocol::DownlinkMsg;
use crate::engine::registry;
use crate::engine::transport::{absent_slot_frame, RoundWindow, WorkerLink, WorkerSchedule};
use crate::engine::{
    RoundCtx, StalePolicy, TrainSpec, Transport, TransportFault, UplinkFrame, WirePayload,
};
use crate::models::Problem;
use crate::F;
use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
// lint:allow(wall_clock, socket poll/reconnect deadlines only; timeouts never feed the trajectory)
use std::time::{Duration, Instant};

const KIND_UPLINK: u8 = 0;
const KIND_DOWNLINK: u8 = 1;
/// Worker → master re-registration after a lost connection.
const KIND_RECONNECT: u8 = 2;
/// Master → rejoining worker: resume round + current model replay.
const KIND_SYNC: u8 = 3;
/// The `round` field of hello/reconnect frames (never a real round).
const HELLO_ROUND: u32 = u32::MAX;
/// Fixed header bytes per frame (len + kind + round + worker + residual).
pub const HEADER_BYTES: u64 = 4 + 1 + 4 + 4 + 8;

struct Frame {
    kind: u8,
    round: u32,
    worker: u32,
    residual: f64,
    payload: Vec<u8>,
}

fn write_frame(s: &mut TcpStream, f: &Frame) -> anyhow::Result<()> {
    let mut head = [0u8; HEADER_BYTES as usize];
    head[0..4].copy_from_slice(&(f.payload.len() as u32).to_le_bytes());
    head[4] = f.kind;
    head[5..9].copy_from_slice(&f.round.to_le_bytes());
    head[9..13].copy_from_slice(&f.worker.to_le_bytes());
    head[13..21].copy_from_slice(&f.residual.to_le_bytes());
    s.write_all(&head)?;
    s.write_all(&f.payload)?;
    Ok(())
}

fn read_frame(s: &mut TcpStream) -> anyhow::Result<Frame> {
    let mut head = [0u8; HEADER_BYTES as usize];
    s.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
    anyhow::ensure!(len <= (1 << 30), "absurd frame length {len}");
    let mut payload = vec![0u8; len];
    s.read_exact(&mut payload)?;
    Ok(Frame {
        kind: head[4],
        round: u32::from_le_bytes(head[5..9].try_into().unwrap()),
        worker: u32::from_le_bytes(head[9..13].try_into().unwrap()),
        residual: f64::from_le_bytes(head[13..21].try_into().unwrap()),
        payload,
    })
}

/// Split one complete frame off the front of a reassembly buffer filled by
/// nonblocking reads; `None` until enough bytes have arrived.
fn take_frame(buf: &mut Vec<u8>) -> anyhow::Result<Option<Frame>> {
    const H: usize = HEADER_BYTES as usize;
    if buf.len() < H {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    anyhow::ensure!(len <= (1 << 30), "absurd frame length {len}");
    if buf.len() < H + len {
        return Ok(None);
    }
    let f = Frame {
        kind: buf[4],
        round: u32::from_le_bytes(buf[5..9].try_into().unwrap()),
        worker: u32::from_le_bytes(buf[9..13].try_into().unwrap()),
        residual: f64::from_le_bytes(buf[13..21].try_into().unwrap()),
        payload: buf[H..H + len].to_vec(),
    };
    buf.drain(..H + len);
    Ok(Some(f))
}

/// Everything a worker thread needs to run (bundled so the spawn sites
/// stay readable).
struct WorkerBoot {
    id: usize,
    n: usize,
    addr: SocketAddr,
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
    /// Chaos knob: vanish (dropping the socket) just before this round —
    /// the thread-level stand-in for `kill -9` on a worker process.
    crash_at: Option<usize>,
}

fn read_apply(
    sock: &mut TcpStream,
    node: &mut dyn WorkerNode,
    round: usize,
) -> anyhow::Result<()> {
    let down = read_frame(sock)?;
    anyhow::ensure!(down.kind == KIND_DOWNLINK, "bad frame kind");
    anyhow::ensure!(down.round == round as u32, "round skew");
    node.apply_downlink(round, &codec::decode(&down.payload)?);
    Ok(())
}

/// [`WorkerLink`] over one socket: downlinks are read (blocking) off the
/// same stream uplinks are written to.
struct SocketLink<'a> {
    sock: &'a mut TcpStream,
    id: usize,
}

impl WorkerLink for SocketLink<'_> {
    fn apply(&mut self, node: &mut dyn WorkerNode, round: usize) -> anyhow::Result<()> {
        read_apply(self.sock, node, round)
    }

    fn send(&mut self, round: usize, bytes: Vec<u8>, residual_norm: f64) -> anyhow::Result<()> {
        write_frame(
            self.sock,
            &Frame {
                kind: KIND_UPLINK,
                round: round as u32,
                worker: self.id as u32,
                residual: residual_norm,
                payload: bytes,
            },
        )
    }
}

/// The shared round body of fresh and rejoining workers — the one
/// [`WorkerSchedule`] every byte-moving transport runs, over a socket
/// link. Returns `None` if the chaos knob fired (simulated kill), else a
/// digest of the final model the transport checks against the master's
/// at `finish`.
fn run_rounds(
    sock: &mut TcpStream,
    node: &mut dyn WorkerNode,
    boot: &WorkerBoot,
    start: usize,
) -> anyhow::Result<Option<u64>> {
    let schedule = WorkerSchedule {
        n: boot.n,
        id: boot.id,
        start,
        crash_at: boot.crash_at,
        problem: boot.problem.as_ref(),
        spec: &boot.spec,
    };
    let mut link = SocketLink { sock, id: boot.id };
    if !schedule.run(node, &mut link)? {
        return Ok(None);
    }
    Ok(Some(digest_f32(node.model())))
}

/// One worker thread: connect, register (fresh hello or reconnect
/// handshake), run the rounds. A rejoining worker that cannot complete
/// its handshake (the master already shut down) exits cleanly with
/// `None` instead of failing the run.
fn tcp_worker_main(
    boot: WorkerBoot,
    mut node: Box<dyn WorkerNode>,
    rejoin: bool,
) -> anyhow::Result<Option<u64>> {
    if rejoin {
        return tcp_rejoin(boot, node);
    }
    let mut sock = TcpStream::connect(boot.addr)?;
    sock.set_nodelay(true)?;
    // identify ourselves once
    write_frame(
        &mut sock,
        &Frame {
            kind: KIND_UPLINK,
            round: HELLO_ROUND,
            worker: boot.id as u32,
            residual: 0.0,
            payload: vec![],
        },
    )?;
    let start = boot.spec.start_round;
    run_rounds(&mut sock, node.as_mut(), &boot, start)
}

/// The rejoin path: reconnect hello → sync frame (resume round + model
/// replay) → rounds from the resume point. A rejoiner that cannot
/// complete the handshake (the master already shut down) exits cleanly
/// with `None` instead of failing the run.
fn tcp_rejoin(boot: WorkerBoot, mut node: Box<dyn WorkerNode>) -> anyhow::Result<Option<u64>> {
    let Ok(mut sock) = TcpStream::connect(boot.addr) else {
        return Ok(None); // master is gone; nothing to rejoin
    };
    sock.set_nodelay(true)?;
    let hello = Frame {
        kind: KIND_RECONNECT,
        round: HELLO_ROUND,
        worker: boot.id as u32,
        residual: 0.0,
        payload: vec![],
    };
    if write_frame(&mut sock, &hello).is_err() {
        return Ok(None);
    }
    sock.set_read_timeout(Some(Duration::from_secs(30)))?;
    let Ok(sync) = read_frame(&mut sock) else {
        return Ok(None); // run finished before we were re-admitted
    };
    anyhow::ensure!(sync.kind == KIND_SYNC, "expected a sync frame after reconnect");
    let Compressed::Dense(model) = codec::decode(&sync.payload)? else {
        anyhow::bail!("sync frame payload was not a dense model");
    };
    // a rejoiner is a fresh node: model replayed, residual state zeroed
    // (empty aux — see WorkerNode::import_state)
    node.import_state(&model, &[])?;
    sock.set_read_timeout(None)?;
    let start = sync.round as usize;
    run_rounds(&mut sock, node.as_mut(), &boot, start)
}

/// The per-worker downlink writer: drains queued broadcasts onto its write
/// half of the socket so the master's read loop never blocks on a full
/// send buffer (the depth ≥ 2 deadlock guard — see the module docs). The
/// feeding channel is bounded at the pipeline depth: a worker that keeps
/// consuming downlinks never backs the master up, while a wedged fleet
/// exerts backpressure instead of queueing the whole run's broadcasts in
/// memory. Exits when the master drops its sender (remaining queued
/// frames are flushed first) or when the peer vanishes mid-write — a
/// rejoining replacement gets a fresh writer plus a model sync, so a
/// broken pipe here is an expected fault, not an error.
fn tcp_downlink_writer(mut sock: TcpStream, rx: Receiver<DownlinkMsg>) -> anyhow::Result<()> {
    while let Ok(m) = rx.recv() {
        let frame = Frame {
            kind: KIND_DOWNLINK,
            round: m.round as u32,
            worker: 0,
            residual: 0.0,
            payload: m.bytes,
        };
        if write_frame(&mut sock, &frame).is_err() {
            return Ok(());
        }
    }
    Ok(())
}

/// One live master-side connection: the nonblocking read half with its
/// reassembly buffer, plus the writer thread feeding the write half.
struct Conn {
    sock: TcpStream,
    buf: Vec<u8>,
    writer_tx: Option<SyncSender<DownlinkMsg>>,
    writer: Option<JoinHandle<anyhow::Result<()>>>,
}

fn spawn_conn(sock: TcpStream, id: usize, depth: usize) -> anyhow::Result<Conn> {
    let w = sock.try_clone()?;
    let (tx, rx) = std::sync::mpsc::sync_channel::<DownlinkMsg>(depth);
    let writer = std::thread::Builder::new()
        .name(format!("dore-tcp-down-{id}"))
        .spawn(move || tcp_downlink_writer(w, rx))?;
    Ok(Conn { sock, buf: Vec::new(), writer_tx: Some(tx), writer: Some(writer) })
}

/// Flush-and-join a connection's writer (its broken-pipe exit is an
/// expected fault path) and drop the socket.
fn close_conn(mut conn: Conn) {
    conn.writer_tx = None;
    if let Some(h) = conn.writer.take() {
        let _ = h.join();
    }
}

/// One nonblocking read attempt's outcome.
enum SockRead {
    Frame(Frame),
    WouldBlock,
    Lost,
}

fn conn_try_read(conn: &mut Conn) -> anyhow::Result<SockRead> {
    loop {
        if let Some(f) = take_frame(&mut conn.buf)? {
            return Ok(SockRead::Frame(f));
        }
        let mut chunk = [0u8; 16384];
        match conn.sock.read(&mut chunk) {
            Ok(0) => return Ok(SockRead::Lost),
            Ok(k) => conn.buf.extend_from_slice(&chunk[..k]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(SockRead::WouldBlock),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                        | ErrorKind::BrokenPipe
                ) =>
            {
                return Ok(SockRead::Lost)
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Partially assembled uplink slots of the round currently being polled
/// (carried across `poll_uplinks → None` returns).
struct Pending {
    round: usize,
    slots: Vec<Option<(Vec<u8>, f64)>>,
    got: usize,
}

/// Socket transport: binds an ephemeral localhost port, runs one OS thread
/// per worker (each with its own socket) and drives the master side from
/// the engine loop with nonblocking reads. Bit-identical iterates to every
/// other transport, at every pipeline depth, on a healthy fleet; see the
/// module docs for the crash/reconnect semantics.
pub struct TcpTransport {
    /// Master-side connections, one slot per worker (`None` = lost).
    conns: Vec<Option<Conn>>,
    /// Kept open for the whole run so lost workers can re-register.
    listener: Option<TcpListener>,
    addr: Option<SocketAddr>,
    handles: Vec<JoinHandle<anyhow::Result<Option<u64>>>>,
    window: RoundWindow,
    /// Master-side replay cache: each worker's last fresh encoded uplink,
    /// kept only under [`StalePolicy::ReuseLast`]. A lost worker's entry
    /// is discarded — its replacement starts with an empty mirror too, so
    /// the two sides stay consistent.
    byte_cache: Vec<Option<Vec<u8>>>,
    /// `(resume round, master iterate)` for reconnect syncs, refreshed
    /// every round via [`Transport::sync_state`].
    model_sync: Option<(usize, Vec<F>)>,
    pending: Option<Pending>,
    faults: Vec<TransportFault>,
    // lint:allow(wall_clock, reconnect-timeout bookkeeping; never feeds the trajectory)
    lost_since: BTreeMap<usize, Instant>,
    /// Auto-respawn attempts per worker (bounded — a replacement that
    /// keeps dying must not crash-loop forever).
    respawns: BTreeMap<usize, usize>,
    respawn: bool,
    crash_at: BTreeMap<usize, usize>,
    poll_wait: Duration,
    reconnect_timeout: Duration,
    spec: Option<TrainSpec>,
    problem: Option<Arc<dyn Problem>>,
}

impl Default for TcpTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl TcpTransport {
    pub fn new() -> Self {
        Self {
            conns: Vec::new(),
            listener: None,
            addr: None,
            handles: Vec::new(),
            window: RoundWindow::default(),
            byte_cache: Vec::new(),
            model_sync: None,
            pending: None,
            faults: Vec::new(),
            lost_since: BTreeMap::new(),
            respawns: BTreeMap::new(),
            respawn: false,
            crash_at: BTreeMap::new(),
            poll_wait: Duration::from_millis(10),
            reconnect_timeout: Duration::from_secs(30),
            spec: None,
            problem: None,
        }
    }

    /// Auto-spawn a fresh local worker thread for a lost connection (it
    /// re-registers through the same reconnect handshake an external
    /// replacement process would use). Off by default: without it a
    /// persistent loss fails the run after
    /// [`TcpTransport::reconnect_timeout`].
    pub fn respawn_lost(mut self, yes: bool) -> Self {
        self.respawn = yes;
        self
    }

    /// Chaos knob: worker `worker`'s thread vanishes (dropping its
    /// socket) just before computing round `round` — the in-tree stand-in
    /// for killing a worker process mid-run.
    pub fn crash_worker(mut self, worker: usize, round: usize) -> Self {
        self.crash_at.insert(worker, round);
        self
    }

    /// How long a worker may stay lost before the run fails loudly
    /// (default 30 s).
    pub fn reconnect_timeout(mut self, timeout: Duration) -> Self {
        self.reconnect_timeout = timeout;
        self
    }

    /// Per-call `poll_uplinks` deadline before it reports "not ready yet"
    /// (`None`) back to the engine (default 10 ms).
    pub fn poll_wait(mut self, wait: Duration) -> Self {
        self.poll_wait = wait;
        self
    }

    fn depth(&self) -> usize {
        self.spec.as_ref().map_or(1, |s| s.pipeline_depth.max(1))
    }

    /// Nonblockingly accept and admit any waiting reconnect hellos. A
    /// botched handshake (stray connector, garbage or absent hello, a
    /// peer that died mid-exchange) drops that socket only — it must
    /// never take the training run down with it.
    fn admit_reconnects(&mut self) -> anyhow::Result<()> {
        let mut fresh: Vec<TcpStream> = Vec::new();
        if let Some(listener) = &self.listener {
            loop {
                match listener.accept() {
                    Ok((s, _)) => fresh.push(s),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) => return Err(e.into()),
                }
            }
        }
        for s in fresh {
            // the socket is dropped on a failed handshake; the run goes on
            let _ = self.admit(s);
        }
        Ok(())
    }

    /// The reconnect/re-register handshake: validate the hello, reply
    /// with the resume round + current model, wire up a fresh writer.
    fn admit(&mut self, mut s: TcpStream) -> anyhow::Result<()> {
        s.set_nodelay(true)?;
        // brief blocking handshake (the connector writes its hello first;
        // sockets accepted from a nonblocking listener may inherit the
        // flag, so set both explicitly)
        s.set_nonblocking(false)?;
        s.set_read_timeout(Some(Duration::from_secs(5)))?;
        let hello = read_frame(&mut s)?;
        anyhow::ensure!(
            hello.kind == KIND_RECONNECT && hello.round == HELLO_ROUND,
            "unexpected frame on a reconnecting socket"
        );
        let id = hello.worker as usize;
        anyhow::ensure!(id < self.conns.len(), "reconnect hello from unknown worker {id}");
        if let Some(old) = self.conns[id].take() {
            // the re-registration supersedes a connection the master still
            // believed live: an unselected worker's EOF can sit unread for
            // a round or more, and a restarted worker may beat the master
            // to noticing. Retire the old socket and admit the new one.
            close_conn(old);
            self.byte_cache[id] = None;
            self.faults.push(TransportFault { worker: id, rejoined: false });
        }
        let (resume, model) = self
            .model_sync
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no sync state available for a reconnecting worker"))?;
        write_frame(
            &mut s,
            &Frame {
                kind: KIND_SYNC,
                round: *resume as u32,
                worker: id as u32,
                residual: 0.0,
                payload: codec::encode(&Compressed::Dense(model.clone())),
            },
        )?;
        s.set_read_timeout(None)?;
        s.set_nonblocking(true)?;
        self.conns[id] = Some(spawn_conn(s, id, self.depth())?);
        self.lost_since.remove(&id);
        self.faults.push(TransportFault { worker: id, rejoined: true });
        Ok(())
    }

    /// Record a dead connection: discard its replay cache, report the
    /// fault, optionally spawn a local replacement.
    #[allow(clippy::disallowed_methods)] // wall-clock: reconnect-timeout bookkeeping only
    fn mark_lost(&mut self, id: usize) -> anyhow::Result<()> {
        if let Some(conn) = self.conns[id].take() {
            close_conn(conn);
        }
        self.byte_cache[id] = None;
        // lint:allow(wall_clock, reconnect-timeout start mark; never feeds the trajectory)
        self.lost_since.insert(id, Instant::now());
        self.faults.push(TransportFault { worker: id, rejoined: false });
        if self.respawn {
            self.spawn_replacement(id)?;
        }
        Ok(())
    }

    /// Spawn a fresh local worker thread that rejoins as `id`. The node
    /// is rebuilt through the registry — by the resolved algorithm name
    /// the session stamped on the spec ([`TrainSpec::algo_name`], which
    /// covers runtime-registered schemes) or by `spec.algo` — with zeroed
    /// residual state; the sync handshake replays the model. A worker
    /// that keeps dying (e.g. its `import_state` is unsupported) is given
    /// up on after a few attempts instead of crash-looping forever.
    fn spawn_replacement(&mut self, id: usize) -> anyhow::Result<()> {
        const MAX_RESPAWNS_PER_WORKER: usize = 5;
        let tries = self.respawns.entry(id).or_insert(0);
        *tries += 1;
        anyhow::ensure!(
            *tries <= MAX_RESPAWNS_PER_WORKER,
            "worker {id} was lost {tries} times; giving up on auto-respawn (does the \
             algorithm support WorkerNode::import_state?)"
        );
        let spec = self.spec.clone().expect("transport started");
        let problem = self.problem.clone().expect("transport started");
        let addr = self.addr.expect("transport started");
        let n = self.conns.len();
        // cheap registry rebuild; the n − 1 unused siblings are dropped
        let x0 = problem.init();
        let (mut fleet, _master) = match &spec.algo_name {
            Some(name) => registry::build_by_name(name, n, &x0, &spec.hp)?,
            None => registry::build_algorithm(spec.algo, n, &x0, &spec.hp)?,
        };
        let node = fleet.swap_remove(id);
        let boot = WorkerBoot { id, n, addr, problem, spec, crash_at: None };
        self.handles.push(
            std::thread::Builder::new()
                .name(format!("dore-tcp-rejoin-{id}"))
                .spawn(move || tcp_worker_main(boot, node, true))?,
        );
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn start(
        &mut self,
        workers: Vec<Box<dyn WorkerNode>>,
        shared_problem: Option<Arc<dyn Problem>>,
        spec: &TrainSpec,
    ) -> anyhow::Result<()> {
        let problem = shared_problem.ok_or_else(|| {
            anyhow::anyhow!(
                "the tcp transport runs workers on their own threads and needs a shared \
                 problem: build the session with Session::shared(Arc<dyn Problem>)"
            )
        })?;
        let n = workers.len();
        self.byte_cache = (0..n).map(|_| None).collect();
        self.window.reset(spec.start_round);
        self.pending = None;
        self.faults.clear();
        self.lost_since.clear();
        self.respawns.clear();
        self.model_sync = None;
        self.spec = Some(spec.clone());
        self.problem = Some(problem.clone());
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        self.addr = Some(addr);

        for (id, node) in workers.into_iter().enumerate() {
            let boot = WorkerBoot {
                id,
                n,
                addr,
                problem: problem.clone(),
                spec: spec.clone(),
                crash_at: self.crash_at.get(&id).copied(),
            };
            self.handles.push(
                std::thread::Builder::new()
                    .name(format!("dore-tcp-{id}"))
                    .spawn(move || tcp_worker_main(boot, node, false))?,
            );
        }

        // accept n connections, map them to worker ids via hello frames
        // (blocking: the fleet connects immediately)
        let mut socks: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (mut s, _) = listener.accept()?;
            s.set_nodelay(true)?;
            let hello = read_frame(&mut s)?;
            anyhow::ensure!(
                hello.kind == KIND_UPLINK && hello.round == HELLO_ROUND,
                "expected hello frame"
            );
            let id = hello.worker as usize;
            anyhow::ensure!(id < n && socks[id].is_none(), "bad hello worker id");
            socks[id] = Some(s);
        }
        // reconnects keep arriving on the same listener, polled
        // nonblockingly from poll_uplinks
        listener.set_nonblocking(true)?;
        self.listener = Some(listener);
        let depth = spec.pipeline_depth.max(1);
        let mut conns = Vec::with_capacity(n);
        for (id, s) in socks.into_iter().enumerate() {
            let s = s.expect("accepted every id");
            s.set_nonblocking(true)?;
            conns.push(Some(spawn_conn(s, id, depth)?));
        }
        self.conns = conns;
        Ok(())
    }

    fn begin_round(
        &mut self,
        round: usize,
        ctx: RoundCtx<'_>,
        inject: Vec<UplinkFrame>,
    ) -> anyhow::Result<()> {
        self.window.begin(round, self.conns.len(), ctx.mask, ctx.spec.stale, inject)
    }

    #[allow(clippy::disallowed_methods)] // wall-clock: nonblocking-poll deadlines only
    fn poll_uplinks(
        &mut self,
        round: usize,
        ctx: RoundCtx<'_>,
    ) -> anyhow::Result<Option<Vec<UplinkFrame>>> {
        self.window.ensure_open(round)?;
        let n = self.conns.len();
        let mask = ctx.mask;
        anyhow::ensure!(mask.len() == n, "round mask covers {} of {n} workers", mask.len());
        let mut pending = match self.pending.take() {
            Some(p) if p.round == round => p,
            _ => Pending { round, slots: (0..n).map(|_| None).collect(), got: 0 },
        };
        let expected = mask.iter().filter(|&&m| m).count();
        // lint:allow(wall_clock, nonblocking-poll deadline; bounds the wait, never the result)
        let deadline = Instant::now() + self.poll_wait;
        // only selected workers transmit this round; absentees' slots are
        // filled at assembly. Workers emit uplinks in round order, so the
        // next frame assembled from a socket is exactly round `round`.
        while pending.got < expected {
            self.admit_reconnects()?;
            let mut progress = false;
            for i in 0..n {
                if !mask[i] || pending.slots[i].is_some() {
                    continue;
                }
                let outcome = match self.conns[i].as_mut() {
                    Some(conn) => conn_try_read(conn)?,
                    None => {
                        // lost: the round stalls until a replacement
                        // re-registers; fail loudly if none ever does
                        if let Some(t0) = self.lost_since.get(&i) {
                            anyhow::ensure!(
                                t0.elapsed() < self.reconnect_timeout,
                                "worker {i} was lost at round {round} and nothing \
                                 re-registered within {:?} (enable \
                                 TcpTransport::respawn_lost or restart the worker)",
                                self.reconnect_timeout
                            );
                        }
                        continue;
                    }
                };
                match outcome {
                    SockRead::Frame(f) => {
                        anyhow::ensure!(
                            f.kind == KIND_UPLINK
                                && f.round == round as u32
                                && f.worker as usize == i,
                            "protocol skew on worker {i} at round {round}"
                        );
                        pending.slots[i] = Some((f.payload, f.residual));
                        pending.got += 1;
                        progress = true;
                    }
                    SockRead::WouldBlock => {}
                    SockRead::Lost => self.mark_lost(i)?,
                }
            }
            if pending.got >= expected {
                break;
            }
            // lint:allow(wall_clock, nonblocking-poll deadline check; engine re-polls)
            if Instant::now() >= deadline {
                // nonblocking contract: not resolvable yet — park the
                // partial assembly, the engine yields and re-polls
                self.pending = Some(pending);
                return Ok(None);
            }
            if !progress {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
        let reuse = ctx.spec.stale == StalePolicy::ReuseLast;
        let mut injected = self.window.take_injected(round, n);
        let frames = pending
            .slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| match s {
                Some((payload, residual_norm)) => {
                    if reuse {
                        self.byte_cache[i] = Some(payload.clone());
                    }
                    UplinkFrame {
                        worker: i,
                        round,
                        payload: Some(WirePayload::Encoded(payload)),
                        residual_norm,
                        compute_seconds: 0.0,
                    }
                }
                // absentee: injected stand-in, replay cache, or empty
                None => absent_slot_frame(&mut injected, &self.byte_cache, reuse, round, i),
            })
            .collect();
        Ok(Some(frames))
    }

    fn push_downlink(
        &mut self,
        round: usize,
        down: &Compressed,
        ctx: RoundCtx<'_>,
    ) -> anyhow::Result<u64> {
        let bytes = codec::encode_with(down, ctx.spec.wire_codec);
        let bits = bytes.len() as u64 * 8;
        // hand off to the per-worker writer threads: the master's loop
        // stays free to keep reading uplinks, which is what prevents the
        // depth ≥ 2 write/write deadlock on large payloads. A lost
        // worker's broadcasts are skipped — the reconnect sync replays
        // the model it missed.
        let mut dead: Vec<usize> = Vec::new();
        for (i, c) in self.conns.iter().enumerate() {
            let Some(conn) = c else { continue };
            let Some(tx) = &conn.writer_tx else { continue };
            if tx.send(DownlinkMsg { round, bytes: bytes.clone() }).is_err() {
                // the writer exited on a broken socket between polls
                dead.push(i);
            }
        }
        for i in dead {
            self.mark_lost(i)?;
        }
        Ok(bits)
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        // stop admitting reconnects first: a straggling replacement
        // blocked on its sync read sees the connection close and exits
        // cleanly (returning None) instead of hanging the join below
        self.listener = None;
        self.addr = None;
        // dropping the senders lets each writer flush its queued
        // downlinks and exit; join writers before workers so the tail
        // broadcasts the workers are draining actually reach them
        for conn in self.conns.iter_mut().filter_map(|c| c.take()) {
            close_conn(conn);
        }
        // every surviving worker reports a digest of its final model;
        // check them against the master's iterate — the cheap invariant
        // that catches any fleet desync a fault path could introduce
        let expect = self.model_sync.take().map(|(_, m)| digest_f32(&m));
        for h in self.handles.drain(..) {
            let digest = h.join().map_err(|_| anyhow::anyhow!("tcp worker panicked"))??;
            if let (Some(d), Some(e)) = (digest, expect) {
                anyhow::ensure!(
                    d == e,
                    "a worker's final model desynced from the master's (digest mismatch)"
                );
            }
        }
        self.conns.clear();
        self.pending = None;
        Ok(())
    }

    fn sync_state(&mut self, next_round: usize, model: &[F]) {
        // reuse the buffer: this runs every round, a reconnect almost never
        match &mut self.model_sync {
            Some((r, buf)) if buf.len() == model.len() => {
                *r = next_round;
                buf.copy_from_slice(model);
            }
            slot => *slot = Some((next_round, model.to_vec())),
        }
    }

    fn drain_faults(&mut self) -> Vec<TransportFault> {
        std::mem::take(&mut self.faults)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;
    use crate::engine::{Session, Threaded};

    #[test]
    fn tcp_matches_inproc_and_threaded_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Diana] {
            let spec = TrainSpec { algo, iters: 20, eval_every: 5, ..Default::default() };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec.clone())
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            let c = Session::shared(p.clone())
                .spec(spec)
                .transport(Threaded::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "{}", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
            assert_eq!(b.loss, c.loss);
        }
    }

    #[test]
    fn tcp_pipelined_depths_match_inproc_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 2, 0.1, 4));
        for depth in [2usize, 3] {
            let spec = TrainSpec {
                algo: AlgorithmKind::Dore,
                iters: 15,
                eval_every: 5,
                pipeline_depth: depth,
                ..Default::default()
            };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec)
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "depth {depth}: tcp diverged from inproc");
            assert_eq!(a.dist_to_opt, b.dist_to_opt, "depth {depth}");
        }
    }

    #[test]
    fn frame_roundtrip() {
        // loopback socket pair via a throwaway listener
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        let f = Frame {
            kind: KIND_DOWNLINK,
            round: 7,
            worker: 3,
            residual: 2.5,
            payload: vec![1, 2, 3, 4, 5],
        };
        write_frame(&mut client, &f).unwrap();
        let g = read_frame(&mut server).unwrap();
        assert_eq!(g.kind, KIND_DOWNLINK);
        assert_eq!(g.round, 7);
        assert_eq!(g.worker, 3);
        assert_eq!(g.residual, 2.5);
        assert_eq!(g.payload, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn take_frame_reassembles_from_partial_reads() {
        let f =
            Frame { kind: KIND_UPLINK, round: 9, worker: 1, residual: 1.5, payload: vec![7; 40] };
        let mut wire = Vec::new();
        wire.extend_from_slice(&(f.payload.len() as u32).to_le_bytes());
        wire.push(f.kind);
        wire.extend_from_slice(&f.round.to_le_bytes());
        wire.extend_from_slice(&f.worker.to_le_bytes());
        wire.extend_from_slice(&f.residual.to_le_bytes());
        wire.extend_from_slice(&f.payload);
        // feed the wire bytes in dribbles: no frame until the last byte
        let mut buf: Vec<u8> = Vec::new();
        for (i, b) in wire.iter().enumerate() {
            buf.push(*b);
            let got = take_frame(&mut buf).unwrap();
            if i + 1 < wire.len() {
                assert!(got.is_none(), "frame surfaced {} bytes early", wire.len() - i - 1);
            } else {
                let g = got.expect("complete frame");
                assert_eq!(g.round, 9);
                assert_eq!(g.payload, vec![7; 40]);
                assert!(buf.is_empty(), "buffer not drained");
            }
        }
        // two frames back-to-back split correctly
        let mut buf2: Vec<u8> = [wire.clone(), wire].concat();
        assert!(take_frame(&mut buf2).unwrap().is_some());
        assert!(take_frame(&mut buf2).unwrap().is_some());
        assert!(take_frame(&mut buf2).unwrap().is_none());
    }
}
