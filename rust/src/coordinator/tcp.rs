//! TCP master for the round engine: the same master/worker state machines
//! and the same [`crate::engine::Session`] loop as every other transport,
//! but over real sockets — the deployment shape the paper's testbed used
//! (PS + workers on Ethernet).
//!
//! The stack is layered: frames and their serialization live in
//! [`crate::engine::protocol`] (one versioned wire format for every
//! byte-moving transport — see its module docs for the header layout and
//! the writev-friendly header/payload split), the master's readiness
//! event loop (epoll poller, slab-allocated connections, zero-copy frame
//! reassembly, nonblocking write queues) lives in [`super::reactor`], the
//! worker-side socket link lives in [`super::link`], and the worker-side
//! session (registration handshake, round schedule, drain) lives in
//! [`super::worker`]. This module is the master: it owns connection
//! admission, round sequencing, and fault bookkeeping.
//!
//! # One reactor, no per-worker threads
//!
//! The master is a single readiness-driven event loop: one
//! [`Reactor`] owns every socket (listener included), each connection
//! pairs a reassembly buffer that decodes frames straight out of the
//! kernel's chunks with a nonblocking buffered write queue, and one
//! broadcast payload is refcounted across every queue instead of being
//! cloned per worker. The master spawns **no** per-connection thread —
//! reader or writer — so a 10,000-connection fleet costs 10,000 fds and
//! slab entries, not 20,000 OS threads (`rust/tests/scale_smoke.rs`
//! proves registration + a gather round at that scale). Per-wake work is
//! proportional to the connections with something to say, so a round
//! costs O(participants), not O(fleet): an idle registered client
//! contributes nothing to the poll loop.
//!
//! Two deployment modes share all of that:
//!
//! * **Local** ([`TcpTransport::new`]): binds an ephemeral localhost port
//!   and spawns one worker *node* thread per worker (the compute side —
//!   the master side stays threadless), each with its own socket — the
//!   in-tree testing shape.
//! * **External** ([`TcpTransport::bind`]): binds a caller-chosen address
//!   and waits (up to [`TcpTransport::registration_timeout`], a monotonic
//!   wall-clock deadline) for `n` `dore-worker` *processes* to register —
//!   the real multi-host fleet. Registration hellos carry the protocol
//!   version (checked by the frame header itself), model dimension, fleet
//!   size, and a fingerprint of the training spec; any mismatch is
//!   rejected with an error naming both sides. Hello reads are
//!   nonblocking and partial-tolerant: a slow or stalled hello parks that
//!   one socket, it can no longer stall the registration of everyone
//!   behind it in the accept queue. At `finish` each worker sends a drain
//!   frame carrying its final-model digest, which the master checks
//!   against its own iterate; the drain is bounded by
//!   [`TcpTransport::drain_timeout`] — a peer that stops reading or never
//!   drains is surfaced through [`Transport::drain_faults`] instead of
//!   hanging `finish()` forever.
//!
//! Pipelining rides the sockets naturally: each worker writes its
//! round-`k` uplink after reading the round-`k − depth` downlink, so up to
//! `depth` uplinks are on the wire per link while the master reduces older
//! rounds. Frames that arrive ahead of the round being polled are parked
//! per-round ([`Parked`], shared with the channel transport) until their
//! turn. Downlink writes are queued per connection and drained on
//! writability, so the master's loop never blocks on a full send buffer —
//! the depth ≥ 2 write/write deadlock guard the old per-worker writer
//! threads existed for, without the threads.
//!
//! # Speed-aware participation
//!
//! Under [`Participation::Fastest`] every worker computes every round
//! speculatively and the master's poll barrier closes after the first `k`
//! uplinks *arrive* — participation is hardware-driven, not seeded; the
//! reactor's event order is the arrival order. The downlink then carries
//! the realized mask as a prefix
//! ([`crate::engine::protocol::encode_masked_downlink`]); a worker whose
//! uplink was dropped rewinds to its pre-round snapshot before applying,
//! so its state is bit-identical to having never computed. Stale
//! speculative uplinks of older rounds are discarded at the next round's
//! poll. The realized masks are recorded by the session (run log +
//! checkpoints) and replaying them through [`Participation::Recorded`]
//! reproduces the run bit-identically.
//!
//! # Fault tolerance
//!
//! [`Transport::poll_uplinks`] returns `None` (the engine yields and
//! re-polls) when a round cannot be resolved within the poll deadline. A
//! worker whose connection drops (EOF / reset mid-frame, or a dead socket
//! discovered on write) is **lost**: its replay cache is discarded, the
//! loss is reported through [`Transport::drain_faults`], and the round
//! stalls until a replacement **re-registers** — the listener stays in
//! the reactor, and a reconnect hello is answered with a sync frame
//! carrying the resume round plus the master's current model (fed each
//! round via [`Transport::sync_state`]). The rejoined worker starts with
//! fresh (zeroed) residual state — the master's `h`/error state carries
//! what the paper's algebra needs, so training proceeds and the fleet's
//! models stay synchronized — but a run with a real crash is *not*
//! bit-identical to an uninterrupted one; use [`crate::engine::FaultPlan`]
//! for deterministic failure injection and
//! [`crate::engine::Session::checkpoint_every`] for bit-exact kill/resume.
//! [`TcpTransport::respawn_lost`] auto-spawns a local replacement thread
//! for a lost worker (the chaos-test path); without it, a worker that
//! stays lost past [`TcpTransport::reconnect_timeout`] fails the run with
//! an actionable error rather than hanging forever.

use super::reactor::{IoEvent, Reactor, SendPayload};
use super::worker::{tcp_worker_main, WorkerBoot};
use crate::algorithms::{digest_f32, WorkerNode};
use crate::compression::{codec, Compressed};
use crate::engine::protocol::{
    encode_masked_downlink, frame_header, parse_drain_digest, spec_fingerprint, Frame, FrameKind,
    HelloBody, SyncBody, MAX_PAYLOAD,
};
use crate::engine::registry;
use crate::engine::transport::{absent_slot_frame, Parked, RoundWindow};
use crate::engine::{
    Participation, RoundCtx, StalePolicy, TrainSpec, Transport, TransportFault, UplinkFrame,
    WirePayload,
};
use crate::models::Problem;
use crate::F;
use anyhow::Context as _;
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
// lint:allow(wall_clock, socket poll/registration/reconnect/drain deadlines only; timeouts never feed the trajectory)
use std::time::{Duration, Instant};

/// Which protocol phase the event loop is serving — it decides what an
/// unregistered peer may say and whether a closed connection is a fault.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// `start` is collecting the fleet's fresh hellos.
    Registering,
    /// Rounds are in flight; unregistered peers may only reconnect.
    Rounds,
    /// `finish` is flushing tails and collecting drain digests; worker
    /// exits are expected, not faults.
    Finishing,
}

/// Socket master: drives the engine side of a socket fleet (local worker
/// threads or external `dore-worker` processes) from one readiness-driven
/// reactor — no per-worker master threads. Bit-identical iterates to
/// every other transport, at every pipeline depth, on a healthy fleet;
/// see the module docs for the crash/reconnect semantics and the two
/// deployment modes.
pub struct TcpTransport {
    n: usize,
    /// The event loop owning every master-side socket (listener included).
    reactor: Option<Reactor>,
    /// Pre-start listener (external mode binds eagerly in [`Self::bind`];
    /// `start` moves it into the reactor).
    listener: Option<TcpListener>,
    addr: Option<SocketAddr>,
    /// External fleet ([`TcpTransport::bind`]): workers are real processes
    /// registering over the network; no local threads are spawned.
    external: bool,
    handles: Vec<JoinHandle<anyhow::Result<Option<u64>>>>,
    window: RoundWindow,
    /// Master-side replay cache: each worker's last fresh encoded uplink,
    /// kept only under [`StalePolicy::ReuseLast`]. A lost worker's entry
    /// is discarded — its replacement starts with an empty mirror too, so
    /// the two sides stay consistent.
    byte_cache: Vec<Option<Vec<u8>>>,
    /// The hello every registering worker must match (version skew is
    /// caught even earlier, by the frame header).
    hello_expect: Option<HelloBody>,
    /// Per-slot Sync payload for fresh registrations: empty = "run from
    /// your own init"; an external resumed run ships the restored state.
    boot_sync: Vec<Vec<u8>>,
    /// `(resume round, master iterate)` for reconnect syncs, refreshed
    /// every round via [`Transport::sync_state`].
    model_sync: Option<(usize, Vec<F>)>,
    /// Worker slot → reactor token of its live connection (`None` = lost).
    slot_token: Vec<Option<usize>>,
    /// Reactor token → worker slot (registered connections only).
    token_slot: BTreeMap<usize, usize>,
    /// Uplinks parked per round: the reactor drains sockets greedily, so
    /// frames for rounds ahead of the one being polled (pipelining, and
    /// round-`start` uplinks arriving mid-registration) wait here.
    parked: BTreeMap<usize, Parked<(Vec<u8>, f64)>>,
    /// Memoized participation masks of later in-flight rounds.
    mask_memo: BTreeMap<usize, Vec<bool>>,
    /// Final-model digests that arrived ahead of (or during) `finish`.
    drain_digests: BTreeMap<usize, u64>,
    /// Scratch event buffer reused across reactor polls.
    sink: Vec<IoEvent>,
    faults: Vec<TransportFault>,
    // lint:allow(wall_clock, reconnect-timeout bookkeeping; never feeds the trajectory)
    lost_since: BTreeMap<usize, Instant>,
    /// Auto-respawn attempts per worker (bounded — a replacement that
    /// keeps dying must not crash-loop forever).
    respawns: BTreeMap<usize, usize>,
    respawn: bool,
    crash_at: BTreeMap<usize, usize>,
    poll_wait: Duration,
    reconnect_timeout: Duration,
    registration_timeout: Duration,
    drain_timeout: Duration,
    spec: Option<TrainSpec>,
    problem: Option<Arc<dyn Problem>>,
}

impl Default for TcpTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl TcpTransport {
    /// Local mode: an ephemeral localhost port plus one worker thread per
    /// node (spawned at `start`).
    pub fn new() -> Self {
        Self {
            n: 0,
            reactor: None,
            listener: None,
            addr: None,
            external: false,
            handles: Vec::new(),
            window: RoundWindow::default(),
            byte_cache: Vec::new(),
            hello_expect: None,
            boot_sync: Vec::new(),
            model_sync: None,
            slot_token: Vec::new(),
            token_slot: BTreeMap::new(),
            parked: BTreeMap::new(),
            mask_memo: BTreeMap::new(),
            drain_digests: BTreeMap::new(),
            sink: Vec::new(),
            faults: Vec::new(),
            lost_since: BTreeMap::new(),
            respawns: BTreeMap::new(),
            respawn: false,
            crash_at: BTreeMap::new(),
            poll_wait: Duration::from_millis(10),
            reconnect_timeout: Duration::from_secs(30),
            registration_timeout: Duration::from_secs(60),
            drain_timeout: Duration::from_secs(30),
            spec: None,
            problem: None,
        }
    }

    /// External mode: bind `addr` (e.g. `"0.0.0.0:7000"`) eagerly and
    /// serve a fleet of `dore-worker` *processes*. No local worker
    /// threads are spawned; `start` waits for `n` registrations, up to
    /// [`TcpTransport::registration_timeout`].
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding master listener on {addr}"))?;
        let mut t = Self::new();
        t.addr = Some(listener.local_addr()?);
        t.listener = Some(listener);
        t.external = true;
        Ok(t)
    }

    /// The bound listener address (useful with a `:0` bind).
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.addr
    }

    /// Auto-spawn a fresh local worker thread for a lost connection (it
    /// re-registers through the same reconnect handshake an external
    /// replacement process would use). Off by default: without it a
    /// persistent loss fails the run after
    /// [`TcpTransport::reconnect_timeout`]. Local mode only — an external
    /// fleet restarts its own `dore-worker` processes.
    pub fn respawn_lost(mut self, yes: bool) -> Self {
        self.respawn = yes;
        self
    }

    /// Chaos knob: worker `worker`'s thread vanishes (dropping its
    /// socket) just before computing round `round` — the in-tree stand-in
    /// for killing a worker process mid-run (the `dore-worker` binary has
    /// `--crash-at` for the real thing).
    pub fn crash_worker(mut self, worker: usize, round: usize) -> Self {
        self.crash_at.insert(worker, round);
        self
    }

    /// How long a worker may stay lost before the run fails loudly
    /// (default 30 s).
    pub fn reconnect_timeout(mut self, timeout: Duration) -> Self {
        self.reconnect_timeout = timeout;
        self
    }

    /// How long `start` waits for the full fleet to register before
    /// giving up on the missing workers (default 60 s). A monotonic
    /// wall-clock deadline: connections that trickle in without
    /// registering no longer extend it.
    pub fn registration_timeout(mut self, timeout: Duration) -> Self {
        self.registration_timeout = timeout;
        self
    }

    /// Per-call `poll_uplinks` deadline before it reports "not ready yet"
    /// (`None`) back to the engine (default 10 ms).
    pub fn poll_wait(mut self, wait: Duration) -> Self {
        self.poll_wait = wait;
        self
    }

    /// Bound on `finish`'s teardown: flushing queued tail downlinks plus
    /// waiting for each worker's drain digest (default 30 s). A peer that
    /// stops reading mid-drain or never sends its digest is dropped and
    /// surfaced via [`Transport::drain_faults`] when the deadline passes,
    /// instead of hanging `finish()` forever.
    pub fn drain_timeout(mut self, timeout: Duration) -> Self {
        self.drain_timeout = timeout;
        self
    }

    fn depth(&self) -> usize {
        self.spec.as_ref().map_or(1, |s| s.pipeline_depth.max(1))
    }

    fn reactor_mut(&mut self) -> &mut Reactor {
        self.reactor.as_mut().expect("transport started")
    }

    /// Remove a token's registration maps; returns the slot it served.
    fn unmap(&mut self, token: usize) -> Option<usize> {
        let i = self.token_slot.remove(&token)?;
        if self.slot_token[i] == Some(token) {
            self.slot_token[i] = None;
        }
        Some(i)
    }

    /// One reactor cycle plus event dispatch. `current` carries the round
    /// being polled and its engine-computed mask (polling phase only).
    fn pump(
        &mut self,
        timeout: Duration,
        phase: Phase,
        current: Option<(usize, &[bool])>,
    ) -> anyhow::Result<()> {
        let mut sink = std::mem::take(&mut self.sink);
        sink.clear();
        let mut res = match self.reactor.as_mut() {
            Some(r) => r.poll_io(timeout, &mut sink),
            None => Err(anyhow::anyhow!("transport not started")),
        };
        if res.is_ok() {
            for ev in sink.drain(..) {
                if let Err(e) = self.on_event(ev, phase, current) {
                    res = Err(e);
                    break;
                }
            }
        }
        sink.clear();
        self.sink = sink;
        res
    }

    fn on_event(
        &mut self,
        ev: IoEvent,
        phase: Phase,
        current: Option<(usize, &[bool])>,
    ) -> anyhow::Result<()> {
        match ev {
            // a fresh connection says nothing until its hello completes
            IoEvent::Accepted(_) => Ok(()),
            IoEvent::Frame { token, frame } => match self.token_slot.get(&token).copied() {
                None => self.process_hello(token, frame, phase),
                Some(i) => self.on_worker_frame(i, frame, phase, current),
            },
            IoEvent::Closed(token) => {
                let Some(i) = self.unmap(token) else {
                    return Ok(()); // a stray peer we never admitted
                };
                match phase {
                    // workers exit right after their drain frame
                    Phase::Finishing => Ok(()),
                    _ => self.lost(i),
                }
            }
            IoEvent::Bad { token, error } => match self.unmap(token) {
                Some(i) => Err(error
                    .context(format!("worker {i}'s connection violated the protocol"))),
                // an unregistered peer sent garbage: fail fast during
                // fresh registration (a misconfigured fleet should be
                // loud), shrug it off mid-run
                None if phase == Phase::Registering => {
                    Err(error.context("a registering connection sent garbage"))
                }
                None => Ok(()),
            },
        }
    }

    /// First complete frame off an unregistered connection — it must be a
    /// hello (fresh) or reconnect (mid-run) handshake.
    fn process_hello(&mut self, token: usize, frame: Frame, phase: Phase) -> anyhow::Result<()> {
        if phase == Phase::Finishing {
            // registration is over and the run is tearing down
            self.reactor_mut().close(token);
            return Ok(());
        }
        let fresh = phase == Phase::Registering;
        if !matches!(frame.kind, FrameKind::Hello | FrameKind::Reconnect) {
            self.reactor_mut().close(token);
            anyhow::ensure!(
                !fresh,
                "expected a hello/reconnect frame on a registering socket, got {:?}",
                frame.kind
            );
            return Ok(());
        }
        let theirs = match HelloBody::decode(&frame.payload) {
            Ok(b) => b,
            Err(e) => {
                self.reactor_mut().close(token);
                if fresh {
                    return Err(e);
                }
                return Ok(());
            }
        };
        let mine = self.hello_expect.expect("transport started");
        if theirs != mine {
            let text = format!(
                "registration mismatch: master expects dim {} / {} workers / spec \
                 fingerprint {:016x}, worker {} announced dim {} / {} workers / \
                 fingerprint {:016x} — launch every dore-worker with the same problem \
                 and training flags as the master",
                mine.dim,
                mine.n_workers,
                mine.fingerprint,
                frame.worker,
                theirs.dim,
                theirs.n_workers,
                theirs.fingerprint,
            );
            // the rejected worker prints something actionable instead of a
            // dead socket: queue the reply, hang up once it flushes
            let header = frame_header(FrameKind::Drain, 0, frame.worker, 0.0, text.len());
            let reactor = self.reactor_mut();
            let _ = reactor.send_frame(token, header, SendPayload::Owned(text.clone().into_bytes()));
            reactor.close_after_flush(token);
            anyhow::ensure!(!fresh, "{text}");
            return Ok(());
        }
        let id = frame.worker as usize;
        if id >= mine.n_workers as usize {
            self.reactor_mut().close(token);
            anyhow::ensure!(
                !fresh,
                "hello from unknown worker slot {id} (fleet of {})",
                mine.n_workers
            );
            return Ok(());
        }
        if fresh {
            anyhow::ensure!(
                frame.kind == FrameKind::Hello,
                "worker {id} sent a reconnect hello during fresh registration"
            );
            anyhow::ensure!(self.slot_token[id].is_none(), "duplicate hello for worker slot {id}");
            let payload = self.boot_sync[id].clone();
            let start = self.spec.as_ref().expect("transport started").start_round;
            let header = frame_header(FrameKind::Sync, start as u32, id as u32, 0.0, payload.len());
            let reactor = self.reactor_mut();
            if !reactor.send_frame(token, header, SendPayload::Owned(payload))? {
                return Ok(()); // died mid-handshake: never registered
            }
            reactor.set_recv_cap(token, MAX_PAYLOAD);
            self.slot_token[id] = Some(token);
            self.token_slot.insert(token, id);
            return Ok(());
        }
        // mid-run: only the reconnect handshake is admitted; anything else
        // (a stray fresh hello, a rejoiner before any sync state exists)
        // drops that socket without taking the run down
        if frame.kind != FrameKind::Reconnect {
            self.reactor_mut().close(token);
            return Ok(());
        }
        let (resume, body) = match self.model_sync.as_ref() {
            Some((r, m)) => (*r, SyncBody { model: m.clone(), aux: Vec::new() }.encode()),
            None => {
                self.reactor_mut().close(token);
                return Ok(());
            }
        };
        if let Some(old) = self.slot_token[id].take() {
            // the re-registration supersedes a connection the master still
            // believed live: an unselected worker's EOF can sit unread for
            // a round or more, and a restarted worker may beat the master
            // to noticing. Retire the old socket and admit the new one.
            self.token_slot.remove(&old);
            self.reactor_mut().close(old);
            self.byte_cache[id] = None;
            self.faults.push(TransportFault { worker: id, rejoined: false });
        }
        // a rejoiner is a fresh node: model replayed, residual state zeroed
        let header = frame_header(FrameKind::Sync, resume as u32, id as u32, 0.0, body.len());
        let reactor = self.reactor_mut();
        if !reactor.send_frame(token, header, SendPayload::Owned(body))? {
            return Ok(()); // died mid-handshake; the run goes on
        }
        reactor.set_recv_cap(token, MAX_PAYLOAD);
        self.slot_token[id] = Some(token);
        self.token_slot.insert(token, id);
        self.lost_since.remove(&id);
        self.faults.push(TransportFault { worker: id, rejoined: true });
        Ok(())
    }

    /// A frame from a registered worker: an uplink to park, a drain digest
    /// to stash, or a protocol violation.
    fn on_worker_frame(
        &mut self,
        i: usize,
        frame: Frame,
        phase: Phase,
        current: Option<(usize, &[bool])>,
    ) -> anyhow::Result<()> {
        match frame.kind {
            FrameKind::Uplink => {
                if phase == Phase::Finishing {
                    return Ok(()); // stale speculative uplinks ahead of the drain
                }
                self.park_uplink(i, frame, current)
            }
            FrameKind::Drain => {
                // the worker's final-model digest, possibly arriving while
                // the last rounds are still being polled
                let digest = parse_drain_digest(&frame.payload)?;
                self.drain_digests.insert(i, digest);
                Ok(())
            }
            other if phase == Phase::Finishing => {
                anyhow::bail!("unexpected {other:?} frame while draining worker {i}")
            }
            other => anyhow::bail!("unexpected {other:?} frame from registered worker {i}"),
        }
    }

    /// Park one uplink into its round's slots, mirroring the channel
    /// transport's validation. The reactor drains sockets greedily, so
    /// frames up to `depth` rounds ahead of the poll (and round-`start`
    /// uplinks arriving mid-registration) are legitimate.
    fn park_uplink(
        &mut self,
        i: usize,
        frame: Frame,
        current: Option<(usize, &[bool])>,
    ) -> anyhow::Result<()> {
        let n = self.n;
        let r = frame.round as usize;
        anyhow::ensure!(
            frame.worker as usize == i,
            "protocol skew on worker {i}: uplink stamped worker {}",
            frame.worker
        );
        let spec = self.spec.as_ref().expect("transport started");
        let fastest_k = match &spec.participation {
            Participation::Fastest { k } => Some(*k),
            _ => None,
        };
        let floor = current.map_or(spec.start_round, |(round, _)| round);
        let ceiling = self.window.next_begin().max(spec.start_round + self.depth());
        if let Some(k) = fastest_k {
            if r < floor {
                return Ok(()); // a dropped speculative uplink from an earlier round
            }
            anyhow::ensure!(
                r < ceiling,
                "protocol skew on worker {i}: uplink for round {r} (rounds open through {})",
                ceiling - 1
            );
            let parked = self.parked.entry(r).or_insert_with(|| Parked::empty(n));
            if parked.got >= k || parked.slots[i].is_some() {
                return Ok(()); // the barrier already closed: a loser's frame
            }
            parked.slots[i] = Some((frame.payload, frame.residual));
            parked.got += 1;
            return Ok(());
        }
        anyhow::ensure!(
            r >= floor && r < ceiling,
            "protocol skew on worker {i}: uplink for round {r} while polling {floor} \
             (rounds open through {})",
            ceiling - 1
        );
        let selected = match current {
            Some((round, mask)) if r == round => mask[i],
            _ => {
                if !self.mask_memo.contains_key(&r) {
                    let m = self.spec.as_ref().expect("transport started").round_mask(r, n);
                    self.mask_memo.insert(r, m);
                }
                self.mask_memo[&r][i]
            }
        };
        anyhow::ensure!(selected, "uplink from unselected worker {i} at round {r}");
        let parked = self.parked.entry(r).or_insert_with(|| Parked::empty(n));
        anyhow::ensure!(
            parked.slots[i].is_none(),
            "duplicate uplink from worker {i} at round {r}"
        );
        parked.slots[i] = Some((frame.payload, frame.residual));
        parked.got += 1;
        Ok(())
    }

    /// Record a lost worker whose connection the reactor already dropped:
    /// discard its replay cache, report the fault, optionally spawn a
    /// local replacement.
    #[allow(clippy::disallowed_methods)] // wall-clock: reconnect-timeout bookkeeping only
    fn lost(&mut self, i: usize) -> anyhow::Result<()> {
        self.byte_cache[i] = None;
        // lint:allow(wall_clock, reconnect-timeout start mark; never feeds the trajectory)
        self.lost_since.insert(i, Instant::now());
        self.faults.push(TransportFault { worker: i, rejoined: false });
        if self.respawn {
            self.spawn_replacement(i)?;
        }
        Ok(())
    }

    /// Spawn a fresh local worker thread that rejoins as `id`. The node
    /// is rebuilt through the registry — by the resolved algorithm name
    /// the session stamped on the spec ([`TrainSpec::algo_name`], which
    /// covers runtime-registered schemes) or by `spec.algo` — with zeroed
    /// residual state; the sync handshake replays the model. A worker
    /// that keeps dying (e.g. its `import_state` is unsupported) is given
    /// up on after a few attempts instead of crash-looping forever.
    fn spawn_replacement(&mut self, id: usize) -> anyhow::Result<()> {
        const MAX_RESPAWNS_PER_WORKER: usize = 5;
        let tries = self.respawns.entry(id).or_insert(0);
        *tries += 1;
        anyhow::ensure!(
            *tries <= MAX_RESPAWNS_PER_WORKER,
            "worker {id} was lost {tries} times; giving up on auto-respawn (does the \
             algorithm support WorkerNode::import_state?)"
        );
        let spec = self.spec.clone().expect("transport started");
        let problem = self.problem.clone().expect("transport started");
        let addr = self.addr.expect("transport started");
        let n = self.n;
        // cheap registry rebuild; the n − 1 unused siblings are dropped
        let x0 = problem.init();
        let (mut fleet, _master) = match &spec.algo_name {
            Some(name) => registry::build_by_name(name, n, &x0, &spec.hp)?,
            None => registry::build_algorithm(spec.algo, n, &x0, &spec.hp)?,
        };
        let node = fleet.swap_remove(id);
        let boot = WorkerBoot { id, n, addr, problem, spec, crash_at: None };
        self.handles.push(
            std::thread::Builder::new()
                .name(format!("dore-tcp-rejoin-{id}"))
                .spawn(move || tcp_worker_main(boot, node, true))?,
        );
        Ok(())
    }

    /// Collect `n` fresh registrations against a **monotonic wall-clock
    /// deadline**. (The old implementation counted consecutive idle
    /// accept ticks, so a trickle of connections extended the timeout
    /// without bound and sub-10 ms timeouts collapsed to one tick.)
    #[allow(clippy::disallowed_methods)] // wall-clock: registration deadline only
    fn accept_registrations(&mut self, n: usize) -> anyhow::Result<()> {
        // lint:allow(wall_clock, registration deadline; never feeds the trajectory)
        let deadline = Instant::now() + self.registration_timeout;
        while self.token_slot.len() < n {
            // lint:allow(wall_clock, registration deadline check)
            let now = Instant::now();
            if now >= deadline {
                let missing: Vec<String> = self
                    .slot_token
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.is_none())
                    .map(|(i, _)| i.to_string())
                    .collect();
                anyhow::bail!(
                    "registration timed out: {} of {n} workers registered within \
                     {:?} (missing slots: {}) — launch the remaining dore-worker \
                     processes (--connect <master> --slot <i>) or raise \
                     TcpTransport::registration_timeout",
                    self.token_slot.len(),
                    self.registration_timeout,
                    missing.join(", ")
                );
            }
            let step = (deadline - now).min(Duration::from_millis(10));
            self.pump(step, Phase::Registering, None)?;
        }
        Ok(())
    }

    /// Fail loudly if a lost worker the current round still needs has
    /// stayed lost past the reconnect timeout.
    fn check_lost_deadline(&self, round: usize, mask: &[bool]) -> anyhow::Result<()> {
        let parked = self.parked.get(&round);
        for (&i, t0) in &self.lost_since {
            if !mask[i] || parked.is_some_and(|p| p.slots[i].is_some()) {
                continue;
            }
            anyhow::ensure!(
                t0.elapsed() < self.reconnect_timeout,
                "worker {i} was lost at round {round} and nothing re-registered within \
                 {:?} (enable TcpTransport::respawn_lost or restart the worker)",
                self.reconnect_timeout
            );
        }
        Ok(())
    }

    /// Drive the reactor until every send queue drained or `deadline`
    /// passed; queues still dirty at the deadline (a peer that stopped
    /// reading mid-drain) are dropped — faulted here in local mode, via
    /// the missing-digest path in external mode (so each drop is surfaced
    /// exactly once).
    // lint:allow(wall_clock, bounded flush deadline parameter; never feeds the trajectory)
    fn flush_or_fault(&mut self, deadline: Instant, fault_stuck: bool) -> anyhow::Result<()> {
        let mut sink = std::mem::take(&mut self.sink);
        sink.clear();
        let flushed = match self.reactor.as_mut() {
            Some(r) => r.flush_all(deadline, &mut sink),
            None => Ok(Vec::new()),
        };
        let mut res = Ok(());
        match flushed {
            Ok(stuck) => {
                for ev in sink.drain(..) {
                    if let Err(e) = self.on_event(ev, Phase::Finishing, None) {
                        res = Err(e);
                        break;
                    }
                }
                if res.is_ok() {
                    for t in stuck {
                        if let Some(i) = self.unmap(t) {
                            if fault_stuck {
                                self.faults.push(TransportFault { worker: i, rejoined: false });
                            }
                        }
                        self.reactor_mut().close(t);
                    }
                }
            }
            Err(e) => res = Err(e),
        }
        sink.clear();
        self.sink = sink;
        res
    }

    /// External-fleet teardown: flush tail downlinks, then keep the loop
    /// turning until every surviving worker's drain digest arrived or the
    /// deadline passed. A worker that never drained becomes a
    /// [`TransportFault`] (the bounded replacement for the old
    /// flush-and-join that could hang forever); a digest that *mismatches*
    /// still fails the run — that is a real desync, not a dead peer.
    #[allow(clippy::disallowed_methods)] // wall-clock: drain deadline only
    fn drain_external(
        &mut self,
        expect: Option<u64>,
        // lint:allow(wall_clock, bounded drain deadline; never feeds the trajectory)
        deadline: Instant,
    ) -> anyhow::Result<()> {
        // slots with a live connection at teardown owe us a digest
        let owed: Vec<usize> = self
            .slot_token
            .iter()
            .enumerate()
            .filter_map(|(i, t)| t.map(|_| i))
            .collect();
        self.flush_or_fault(deadline, false)?;
        loop {
            let missing = owed
                .iter()
                .any(|&i| !self.drain_digests.contains_key(&i) && self.slot_token[i].is_some());
            if !missing {
                break;
            }
            // lint:allow(wall_clock, bounded drain deadline check)
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let step = (deadline - now).min(Duration::from_millis(10));
            self.pump(step, Phase::Finishing, None)?;
        }
        for i in owed {
            match self.drain_digests.get(&i) {
                Some(&d) => {
                    if let Some(e) = expect {
                        anyhow::ensure!(
                            d == e,
                            "worker {i}'s final model desynced from the master's \
                             (digest {d:016x}, master {e:016x})"
                        );
                    }
                }
                None => {
                    // stalled or died mid-drain: bounded and surfaced
                    // instead of hanging finish() forever
                    self.faults.push(TransportFault { worker: i, rejoined: false });
                }
            }
        }
        Ok(())
    }

    /// Local teardown: flush tails (bounded), drop every socket, join the
    /// worker threads and check their final-model digests.
    // lint:allow(wall_clock, bounded teardown deadline parameter; never feeds the trajectory)
    fn finish_local(&mut self, expect: Option<u64>, deadline: Instant) -> anyhow::Result<()> {
        self.flush_or_fault(deadline, true)?;
        let tokens: Vec<usize> = self.token_slot.keys().copied().collect();
        let reactor = self.reactor_mut();
        for t in tokens {
            reactor.close(t);
        }
        for h in self.handles.drain(..) {
            let digest = h.join().map_err(|_| anyhow::anyhow!("tcp worker panicked"))??;
            if let (Some(d), Some(e)) = (digest, expect) {
                anyhow::ensure!(
                    d == e,
                    "a worker's final model desynced from the master's (digest mismatch)"
                );
            }
        }
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn start(
        &mut self,
        workers: Vec<Box<dyn WorkerNode>>,
        shared_problem: Option<Arc<dyn Problem>>,
        spec: &TrainSpec,
    ) -> anyhow::Result<()> {
        let problem = shared_problem.ok_or_else(|| {
            anyhow::anyhow!(
                "the tcp transport runs workers on their own threads and needs a shared \
                 problem: build the session with Session::shared(Arc<dyn Problem>)"
            )
        })?;
        anyhow::ensure!(
            !(self.external && self.respawn),
            "respawn_lost spawns local threads; an external fleet restarts its own \
             dore-worker processes instead"
        );
        let n = workers.len();
        let dim = problem.dim();
        self.n = n;
        self.byte_cache = (0..n).map(|_| None).collect();
        self.slot_token = (0..n).map(|_| None).collect();
        self.token_slot.clear();
        self.window.reset(spec.start_round);
        self.parked.clear();
        self.mask_memo.clear();
        self.drain_digests.clear();
        self.faults.clear();
        self.lost_since.clear();
        self.respawns.clear();
        self.model_sync = None;
        self.spec = Some(spec.clone());
        self.problem = Some(problem.clone());
        self.hello_expect = Some(HelloBody {
            dim: dim as u32,
            n_workers: n as u32,
            fingerprint: spec_fingerprint(spec, dim, n),
        });

        let listener = match self.listener.take() {
            Some(l) => l, // external: bound eagerly by `bind`
            None => TcpListener::bind("127.0.0.1:0")?,
        };
        let addr = listener.local_addr()?;
        self.addr = Some(addr);
        // registrations and reconnects arrive on the same listener, owned
        // by the reactor alongside every accepted socket
        let mut reactor = Reactor::new()?;
        reactor.listen(listener)?;
        self.reactor = Some(reactor);

        if self.external {
            // real processes own the nodes; ship the restored state on a
            // resumed run, otherwise an empty Sync payload means "run from
            // your own deterministic init"
            self.boot_sync = if spec.start_round > 0 {
                workers
                    .iter()
                    .map(|w| {
                        SyncBody { model: w.model().to_vec(), aux: w.export_state() }.encode()
                    })
                    .collect()
            } else {
                (0..n).map(|_| Vec::new()).collect()
            };
        } else {
            self.boot_sync = (0..n).map(|_| Vec::new()).collect();
            for (id, node) in workers.into_iter().enumerate() {
                let boot = WorkerBoot {
                    id,
                    n,
                    addr,
                    problem: problem.clone(),
                    spec: spec.clone(),
                    crash_at: self.crash_at.get(&id).copied(),
                };
                self.handles.push(
                    std::thread::Builder::new()
                        .name(format!("dore-tcp-{id}"))
                        .spawn(move || tcp_worker_main(boot, node, false))?,
                );
            }
        }
        self.accept_registrations(n)
    }

    fn begin_round(
        &mut self,
        round: usize,
        ctx: RoundCtx<'_>,
        inject: Vec<UplinkFrame>,
    ) -> anyhow::Result<()> {
        self.window.begin(round, self.n, ctx.mask, ctx.spec.stale, inject)
    }

    #[allow(clippy::disallowed_methods)] // wall-clock: nonblocking-poll deadlines only
    fn poll_uplinks(
        &mut self,
        round: usize,
        ctx: RoundCtx<'_>,
    ) -> anyhow::Result<Option<Vec<UplinkFrame>>> {
        self.window.ensure_open(round)?;
        let n = self.n;
        let mask = ctx.mask;
        anyhow::ensure!(mask.len() == n, "round mask covers {} of {n} workers", mask.len());
        let fastest_k = match &ctx.spec.participation {
            Participation::Fastest { k } => Some(*k),
            _ => None,
        };
        // drop parked rounds the engine has moved past: under fastest
        // these are losers' speculative frames, discarded exactly like the
        // old per-socket reads discarded them
        let keep = self.parked.split_off(&round);
        self.parked = keep;
        let keep = self.mask_memo.split_off(&round);
        self.mask_memo = keep;
        // speed-aware mode closes the barrier after the first k arrivals
        // (arrival order = reactor event order); derived masks await
        // exactly the selected subset
        let expected = fastest_k.unwrap_or_else(|| mask.iter().filter(|&&m| m).count());
        // lint:allow(wall_clock, nonblocking-poll deadline; bounds the wait, never the result)
        let deadline = Instant::now() + self.poll_wait;
        while self.parked.get(&round).map_or(0, |p| p.got) < expected {
            // lost: the round stalls until a replacement re-registers;
            // fail loudly if none ever does
            self.check_lost_deadline(round, mask)?;
            // lint:allow(wall_clock, nonblocking-poll deadline check; engine re-polls)
            let now = Instant::now();
            if now >= deadline {
                // nonblocking contract: not resolvable yet — the partial
                // assembly stays parked, the engine yields and re-polls
                return Ok(None);
            }
            let step = (deadline - now).min(Duration::from_millis(5));
            self.pump(step, Phase::Rounds, Some((round, mask)))?;
        }
        let slots = self
            .parked
            .remove(&round)
            .map_or_else(|| (0..n).map(|_| None).collect(), |p| p.slots);
        self.mask_memo.remove(&round);
        let reuse = ctx.spec.stale == StalePolicy::ReuseLast;
        let mut injected = self.window.take_injected(round, n);
        let frames = slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| match s {
                Some((payload, residual_norm)) => {
                    if reuse {
                        self.byte_cache[i] = Some(payload.clone());
                    }
                    UplinkFrame {
                        worker: i,
                        round,
                        payload: Some(WirePayload::Encoded(payload)),
                        residual_norm,
                        compute_seconds: 0.0,
                    }
                }
                // absentee: injected stand-in, replay cache, or empty
                None => absent_slot_frame(&mut injected, &self.byte_cache, reuse, round, i),
            })
            .collect();
        Ok(Some(frames))
    }

    fn push_downlink(
        &mut self,
        round: usize,
        down: &Compressed,
        ctx: RoundCtx<'_>,
    ) -> anyhow::Result<u64> {
        let bytes = codec::encode_with(down, ctx.spec.wire_codec);
        let bits = bytes.len() as u64 * 8;
        // under fastest the broadcast carries the realized mask (the
        // session passes it as ctx.mask at push time) so every worker
        // learns whether its speculative uplink stood; the prefix is
        // per-frame overhead, accounted like the frame header
        let wire = if ctx.spec.participation.is_fastest() {
            encode_masked_downlink(ctx.mask, &bytes)
        } else {
            bytes
        };
        // one refcounted broadcast payload shared by every connection's
        // write queue (the writev split: 24 header bytes + the shared
        // slice, never a per-worker copy); queues drain on writability, so
        // the master's loop never blocks on a full send buffer — the
        // depth ≥ 2 write/write deadlock guard. A lost worker's broadcasts
        // are skipped — the reconnect sync replays the model it missed.
        let payload: Arc<[u8]> = wire.into();
        let header = frame_header(FrameKind::Downlink, round as u32, 0, 0.0, payload.len());
        let targets: Vec<(usize, usize)> = self
            .slot_token
            .iter()
            .enumerate()
            .filter_map(|(i, t)| t.map(|t| (i, t)))
            .collect();
        let mut dead: Vec<(usize, usize)> = Vec::new();
        for (i, t) in targets {
            let delivered = self
                .reactor_mut()
                .send_frame(t, header, SendPayload::Shared(payload.clone()))?;
            if !delivered {
                // the peer died on the spot; the reactor dropped it
                dead.push((i, t));
            }
        }
        for (i, t) in dead {
            self.token_slot.remove(&t);
            if self.slot_token[i] == Some(t) {
                self.slot_token[i] = None;
            }
            self.lost(i)?;
        }
        Ok(bits)
    }

    #[allow(clippy::disallowed_methods)] // wall-clock: bounded teardown drain only
    fn finish(&mut self) -> anyhow::Result<()> {
        // stop accepting first: a straggling replacement blocked on its
        // sync read sees the connection close and exits cleanly
        // (returning None) instead of hanging the joins below
        if let Some(r) = self.reactor.as_mut() {
            r.unlisten();
        }
        self.addr = None;
        // the cheap invariant that catches any fleet desync a fault path
        // could introduce: every surviving worker reports a digest of its
        // final model, checked against the master's iterate
        let expect = self.model_sync.take().map(|(_, m)| digest_f32(&m));
        // lint:allow(wall_clock, bounded teardown deadline; never feeds the trajectory)
        let deadline = Instant::now() + self.drain_timeout;
        let res = if self.external {
            self.drain_external(expect, deadline)
        } else {
            self.finish_local(expect, deadline)
        };
        self.reactor = None;
        self.slot_token.clear();
        self.token_slot.clear();
        self.parked.clear();
        self.mask_memo.clear();
        self.drain_digests.clear();
        res
    }

    fn sync_state(&mut self, next_round: usize, model: &[F]) {
        // reuse the buffer: this runs every round, a reconnect almost never
        match &mut self.model_sync {
            Some((r, buf)) if buf.len() == model.len() => {
                *r = next_round;
                buf.copy_from_slice(model);
            }
            slot => *slot = Some((next_round, model.to_vec())),
        }
    }

    fn drain_faults(&mut self) -> Vec<TransportFault> {
        std::mem::take(&mut self.faults)
    }

    fn supports_fastest(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;
    use crate::engine::protocol::{read_frame, write_frame};
    use crate::engine::{Session, Threaded};
    use std::io::Write as _;
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn tcp_matches_inproc_and_threaded_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Diana] {
            let spec = TrainSpec { algo, iters: 20, eval_every: 5, ..Default::default() };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec.clone())
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            let c = Session::shared(p.clone())
                .spec(spec)
                .transport(Threaded::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "{}", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
            assert_eq!(b.loss, c.loss);
            assert_eq!(a.final_model_digest, b.final_model_digest);
        }
    }

    #[test]
    fn tcp_pipelined_depths_match_inproc_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 2, 0.1, 4));
        for depth in [2usize, 3] {
            let spec = TrainSpec {
                algo: AlgorithmKind::Dore,
                iters: 15,
                eval_every: 5,
                pipeline_depth: depth,
                ..Default::default()
            };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec)
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "depth {depth}: tcp diverged from inproc");
            assert_eq!(a.dist_to_opt, b.dist_to_opt, "depth {depth}");
        }
    }

    #[test]
    fn fastest_over_tcp_records_k_sized_masks_and_replays_on_inproc() {
        use crate::engine::participation::MaskSchedule;
        let p = Arc::new(linreg_problem(50, 12, 4, 0.1, 9));
        let spec = TrainSpec {
            algo: AlgorithmKind::Dore,
            iters: 8,
            eval_every: 2,
            participation: Participation::Fastest { k: 3 },
            ..Default::default()
        };
        let live = Session::shared(p.clone())
            .spec(spec.clone())
            .transport(TcpTransport::new())
            .run()
            .unwrap();
        assert_eq!(live.realized_masks.len(), 8);
        for (r, m) in live.realized_masks.iter().enumerate() {
            assert_eq!(m.len(), 4, "round {r}");
            assert_eq!(m.iter().filter(|&&b| b).count(), 3, "round {r}: {m:?}");
        }
        // replaying the recorded masks on the zero-copy reference transport
        // reproduces the run bit-for-bit — arrival order became data
        let sched = MaskSchedule { masks: live.realized_masks.clone() };
        let replay_spec = TrainSpec {
            participation: Participation::Recorded(Arc::new(sched)),
            ..spec
        };
        let replay = Session::new(p.as_ref()).spec(replay_spec).run().unwrap();
        assert_eq!(live.loss, replay.loss);
        assert_eq!(live.final_model_digest, replay.final_model_digest);
        assert_eq!(live.realized_masks, replay.realized_masks);
    }

    /// Satellite bugfix pin: the registration timeout is a monotonic
    /// wall-clock deadline. The old idle-tick counter reset on every
    /// accept, so a trickle of connections that never registered extended
    /// the timeout without bound.
    #[test]
    fn registration_deadline_is_wall_time_not_idle_ticks() {
        let p = Arc::new(linreg_problem(20, 8, 2, 0.1, 7));
        let spec = TrainSpec { algo: AlgorithmKind::Dore, iters: 2, ..Default::default() };
        let mut t = TcpTransport::bind("127.0.0.1:0")
            .unwrap()
            .registration_timeout(Duration::from_millis(200));
        let addr = t.local_addr().unwrap();
        // a trickle of connections that never send a hello: each accept
        // reset the old idle counter, deferring the timeout forever
        let dripper = std::thread::spawn(move || {
            let mut held = Vec::new();
            for _ in 0..40 {
                if let Ok(s) = TcpStream::connect(addr) {
                    held.push(s); // keep them open so they look alive
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        });
        let x0 = p.init();
        let (fleet, _master) = registry::build_algorithm(spec.algo, 2, &x0, &spec.hp).unwrap();
        let t0 = std::time::Instant::now();
        let err = t.start(fleet, Some(p.clone()), &spec).unwrap_err().to_string();
        let waited = t0.elapsed();
        assert!(err.contains("registration timed out"), "{err}");
        assert!(err.contains("missing slots: 0, 1"), "{err}");
        assert!(
            waited < Duration::from_secs(5),
            "deadline must not be extended by the connection trickle (waited {waited:?})"
        );
        drop(t);
        dripper.join().unwrap();
    }

    /// Satellite bugfix pin: a slow-loris peer dribbling a partial hello
    /// parks only its own socket. The old blocking per-accept hello read
    /// (5 s `set_read_timeout`) stalled — and on timeout, failed —
    /// registration of every worker queued behind it.
    #[test]
    fn slow_loris_hello_does_not_stall_registration() {
        use crate::coordinator::run_remote_worker;
        let p = Arc::new(linreg_problem(40, 10, 2, 0.1, 5));
        let spec = TrainSpec { algo: AlgorithmKind::Dore, iters: 6, eval_every: 3, ..Default::default() };
        let inproc = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();

        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        // the loris connects FIRST and dribbles 3 bytes of a valid header,
        // then holds the socket open for the whole run
        let loris_stop = stop.clone();
        let loris = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let real = Frame {
                kind: FrameKind::Hello,
                round: 0,
                worker: 0,
                residual: 0.0,
                payload: vec![0; 16],
            }
            .to_bytes();
            s.write_all(&real[..3]).unwrap();
            while !loris_stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(20));
            }
        });
        // give the loris the front of the accept queue
        std::thread::sleep(Duration::from_millis(100));
        let workers: Vec<_> = (0..2)
            .map(|slot| {
                let p = p.clone();
                let spec = spec.clone();
                std::thread::spawn(move || {
                    run_remote_worker(&addr.to_string(), slot, 2, false, None, p, spec)
                })
            })
            .collect();
        let live = Session::shared(p.clone()).spec(spec).transport(t).run().unwrap();
        assert_eq!(live.final_model_digest, inproc.final_model_digest);
        assert_eq!(live.loss, inproc.loss);
        for w in workers {
            w.join().unwrap().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        loris.join().unwrap();
    }

    /// Satellite bugfix pin: `finish()` is bounded. A peer that reads its
    /// downlink but never drains used to hang the master's teardown; now
    /// the drain deadline passes, the drop lands in `drain_faults`, and
    /// `finish` returns.
    #[test]
    fn finish_is_bounded_when_a_peer_never_drains() {
        let p = Arc::new(linreg_problem(20, 6, 1, 0.1, 3));
        let spec = TrainSpec { algo: AlgorithmKind::Sgd, iters: 1, ..Default::default() };
        let mut t = TcpTransport::bind("127.0.0.1:0")
            .unwrap()
            .drain_timeout(Duration::from_millis(200));
        let addr = t.local_addr().unwrap();
        let dim = p.dim();
        let fp = spec_fingerprint(&spec, dim, 1);
        let stop = Arc::new(AtomicBool::new(false));
        let wedge_stop = stop.clone();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let hello = HelloBody { dim: dim as u32, n_workers: 1, fingerprint: fp };
            write_frame(
                &mut s,
                &Frame {
                    kind: FrameKind::Hello,
                    round: 0,
                    worker: 0,
                    residual: 0.0,
                    payload: hello.encode(),
                },
            )
            .unwrap();
            let sync = read_frame(&mut s).unwrap();
            assert_eq!(sync.kind, FrameKind::Sync);
            // uplink round 0, then read the downlink — and wedge: no
            // drain digest, socket held open
            write_frame(
                &mut s,
                &Frame {
                    kind: FrameKind::Uplink,
                    round: 0,
                    worker: 0,
                    residual: 0.0,
                    payload: vec![1, 2, 3],
                },
            )
            .unwrap();
            let down = read_frame(&mut s).unwrap();
            assert_eq!(down.kind, FrameKind::Downlink);
            while !wedge_stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(10));
            }
            drop(s);
        });
        let x0 = p.init();
        let (fleet, _master) = registry::build_algorithm(spec.algo, 1, &x0, &spec.hp).unwrap();
        t.start(fleet, Some(p.clone()), &spec).unwrap();
        let mask = vec![true];
        let ctx = RoundCtx { problem: p.as_ref(), spec: &spec, mask: &mask };
        t.begin_round(0, ctx, Vec::new()).unwrap();
        let frames = loop {
            let ctx = RoundCtx { problem: p.as_ref(), spec: &spec, mask: &mask };
            if let Some(f) = t.poll_uplinks(0, ctx).unwrap() {
                break f;
            }
        };
        assert_eq!(frames.len(), 1);
        let ctx = RoundCtx { problem: p.as_ref(), spec: &spec, mask: &mask };
        t.push_downlink(0, &Compressed::Dense(vec![0.0; dim]), ctx).unwrap();
        let t0 = std::time::Instant::now();
        t.finish().unwrap();
        let took = t0.elapsed();
        assert!(
            took < Duration::from_secs(5),
            "finish() must be bounded by drain_timeout (took {took:?})"
        );
        let faults = t.drain_faults();
        assert!(
            faults.iter().any(|f| f.worker == 0 && !f.rejoined),
            "the wedged peer must surface through drain_faults: {faults:?}"
        );
        stop.store(true, Ordering::Relaxed);
        client.join().unwrap();
    }
}
