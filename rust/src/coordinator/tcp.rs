//! TCP transport for the round engine: the same master/worker state
//! machines and the same [`crate::engine::Session`] loop as every other
//! transport, but over real sockets with a length-prefixed frame protocol —
//! the deployment shape the paper's testbed used (PS + workers on
//! Ethernet).
//!
//! Frame layout (little-endian):
//! ```text
//! [u32 payload_len][u8 kind][u32 round][u32 worker][f64 residual][payload]
//! ```
//! `kind` is 0 = uplink, 1 = downlink; `payload` is a
//! [`crate::compression::codec`] buffer. Byte accounting counts payload
//! bytes only (header bytes are fixed per message and reported separately),
//! keeping the numbers comparable with the other transports.

use crate::algorithms::WorkerNode;
use crate::compression::{codec, Compressed};
use crate::engine::transport::WorkerRoundDriver;
use crate::engine::{
    RoundCtx, Session, StalePolicy, TrainSpec, Transport, UplinkFrame, WirePayload,
};
use crate::metrics::RunMetrics;
use crate::models::Problem;
use crate::F;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;

const KIND_UPLINK: u8 = 0;
const KIND_DOWNLINK: u8 = 1;
/// Fixed header bytes per frame (len + kind + round + worker + residual).
pub const HEADER_BYTES: u64 = 4 + 1 + 4 + 4 + 8;

struct Frame {
    kind: u8,
    round: u32,
    worker: u32,
    residual: f64,
    payload: Vec<u8>,
}

fn write_frame(s: &mut TcpStream, f: &Frame) -> anyhow::Result<()> {
    let mut head = [0u8; HEADER_BYTES as usize];
    head[0..4].copy_from_slice(&(f.payload.len() as u32).to_le_bytes());
    head[4] = f.kind;
    head[5..9].copy_from_slice(&f.round.to_le_bytes());
    head[9..13].copy_from_slice(&f.worker.to_le_bytes());
    head[13..21].copy_from_slice(&f.residual.to_le_bytes());
    s.write_all(&head)?;
    s.write_all(&f.payload)?;
    Ok(())
}

fn read_frame(s: &mut TcpStream) -> anyhow::Result<Frame> {
    let mut head = [0u8; HEADER_BYTES as usize];
    s.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
    anyhow::ensure!(len <= (1 << 30), "absurd frame length {len}");
    let mut payload = vec![0u8; len];
    s.read_exact(&mut payload)?;
    Ok(Frame {
        kind: head[4],
        round: u32::from_le_bytes(head[5..9].try_into().unwrap()),
        worker: u32::from_le_bytes(head[9..13].try_into().unwrap()),
        residual: f64::from_le_bytes(head[13..21].try_into().unwrap()),
        payload,
    })
}

fn tcp_worker_loop(
    id: usize,
    n: usize,
    mut node: Box<dyn WorkerNode>,
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
    addr: SocketAddr,
) -> anyhow::Result<()> {
    let mut sock = TcpStream::connect(addr)?;
    sock.set_nodelay(true)?;
    // identify ourselves once
    write_frame(
        &mut sock,
        &Frame {
            kind: KIND_UPLINK,
            round: u32::MAX,
            worker: id as u32,
            residual: 0.0,
            payload: vec![],
        },
    )?;
    let mut grad = vec![0.0 as F; problem.dim()];
    let mut driver = WorkerRoundDriver::new(&spec, n);
    for k in 0..spec.iters {
        if let Some((payload, residual)) =
            driver.round(node.as_mut(), problem.as_ref(), &spec, k, id, &mut grad)
        {
            write_frame(
                &mut sock,
                &Frame { kind: KIND_UPLINK, round: k as u32, worker: id as u32, residual, payload },
            )?;
        }
        let down = read_frame(&mut sock)?;
        anyhow::ensure!(down.kind == KIND_DOWNLINK, "bad frame kind");
        anyhow::ensure!(down.round == k as u32, "round skew");
        node.apply_downlink(k, &codec::decode(&down.payload)?);
    }
    Ok(())
}

/// Socket transport: binds an ephemeral localhost port, runs one OS thread
/// per worker (each with its own socket) and drives the master side from
/// the engine loop. Bit-identical iterates to every other transport.
#[derive(Default)]
pub struct TcpTransport {
    socks: Vec<TcpStream>,
    handles: Vec<JoinHandle<anyhow::Result<()>>>,
    /// Master-side replay cache: each worker's last fresh encoded uplink,
    /// kept only under [`StalePolicy::ReuseLast`].
    byte_cache: Vec<Option<Vec<u8>>>,
}

impl TcpTransport {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn start(
        &mut self,
        workers: Vec<Box<dyn WorkerNode>>,
        shared_problem: Option<Arc<dyn Problem>>,
        spec: &TrainSpec,
    ) -> anyhow::Result<()> {
        let problem = shared_problem.ok_or_else(|| {
            anyhow::anyhow!(
                "the tcp transport runs workers on their own threads and needs a shared \
                 problem: build the session with Session::shared(Arc<dyn Problem>)"
            )
        })?;
        let n = workers.len();
        self.byte_cache = (0..n).map(|_| None).collect();
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;

        for (id, node) in workers.into_iter().enumerate() {
            let p = problem.clone();
            let s = spec.clone();
            self.handles.push(
                std::thread::Builder::new()
                    .name(format!("dore-tcp-{id}"))
                    .spawn(move || tcp_worker_loop(id, n, node, p, s, addr))?,
            );
        }

        // accept n connections, map them to worker ids via hello frames
        let mut socks: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (mut s, _) = listener.accept()?;
            s.set_nodelay(true)?;
            let hello = read_frame(&mut s)?;
            anyhow::ensure!(hello.round == u32::MAX, "expected hello frame");
            let id = hello.worker as usize;
            anyhow::ensure!(id < n && socks[id].is_none(), "bad hello worker id");
            socks[id] = Some(s);
        }
        self.socks = socks.into_iter().map(|s| s.expect("accepted every id")).collect();
        Ok(())
    }

    fn send_uplink(&mut self, _frame: UplinkFrame) -> anyhow::Result<()> {
        anyhow::bail!(
            "tcp transport: uplinks originate on worker sockets; engine-side injection \
             is not supported"
        )
    }

    fn gather(&mut self, round: usize, ctx: RoundCtx<'_>) -> anyhow::Result<Vec<UplinkFrame>> {
        let n = self.socks.len();
        let mask = ctx.mask;
        anyhow::ensure!(mask.len() == n, "round mask covers {} of {n} workers", mask.len());
        let reuse = ctx.spec.stale == StalePolicy::ReuseLast;
        let mut frames = Vec::with_capacity(n);
        for (i, s) in self.socks.iter_mut().enumerate() {
            // only selected workers transmit this round; absentees' slots
            // are filled from the replay cache (reuse-last) or left empty
            if !mask[i] {
                frames.push(UplinkFrame {
                    worker: i,
                    round,
                    payload: self.byte_cache[i]
                        .as_ref()
                        .filter(|_| reuse)
                        .map(|b| WirePayload::Encoded(b.clone())),
                    residual_norm: 0.0,
                    compute_seconds: 0.0,
                });
                continue;
            }
            let f = read_frame(s)?;
            anyhow::ensure!(
                f.kind == KIND_UPLINK && f.round == round as u32 && f.worker as usize == i,
                "protocol skew on worker {i} at round {round}"
            );
            if reuse {
                self.byte_cache[i] = Some(f.payload.clone());
            }
            frames.push(UplinkFrame {
                worker: i,
                round,
                payload: Some(WirePayload::Encoded(f.payload)),
                residual_norm: f.residual,
                compute_seconds: 0.0,
            });
        }
        Ok(frames)
    }

    fn broadcast(
        &mut self,
        round: usize,
        down: &Compressed,
        _ctx: RoundCtx<'_>,
    ) -> anyhow::Result<u64> {
        let bytes = codec::encode(down);
        let bits = bytes.len() as u64 * 8;
        for s in self.socks.iter_mut() {
            write_frame(
                s,
                &Frame {
                    kind: KIND_DOWNLINK,
                    round: round as u32,
                    worker: 0,
                    residual: 0.0,
                    payload: bytes.clone(),
                },
            )?;
        }
        Ok(bits)
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        self.socks.clear();
        for h in self.handles.drain(..) {
            h.join().map_err(|_| anyhow::anyhow!("tcp worker panicked"))??;
        }
        Ok(())
    }
}

/// Run a training job over localhost TCP.
#[deprecated(
    note = "use engine::Session::shared(problem).spec(spec).transport(TcpTransport::new()).run()"
)]
pub fn run_distributed_tcp(
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
) -> anyhow::Result<RunMetrics> {
    Session::shared(problem).spec(spec).transport(TcpTransport::new()).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;
    use crate::engine::Threaded;

    #[test]
    fn tcp_matches_inproc_and_threaded_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Diana] {
            let spec = TrainSpec { algo, iters: 20, eval_every: 5, ..Default::default() };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec.clone())
                .transport(TcpTransport::new())
                .run()
                .unwrap();
            let c = Session::shared(p.clone())
                .spec(spec)
                .transport(Threaded::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "{}", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
            assert_eq!(b.loss, c.loss);
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_tcp_shim_still_runs() {
        let p = Arc::new(linreg_problem(60, 16, 2, 0.1, 4));
        let spec = TrainSpec { iters: 5, eval_every: 2, ..Default::default() };
        let m = run_distributed_tcp(p, spec).unwrap();
        assert_eq!(m.total_rounds, 5);
    }

    #[test]
    fn frame_roundtrip() {
        // loopback socket pair via a throwaway listener
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        let f = Frame {
            kind: KIND_DOWNLINK,
            round: 7,
            worker: 3,
            residual: 2.5,
            payload: vec![1, 2, 3, 4, 5],
        };
        write_frame(&mut client, &f).unwrap();
        let g = read_frame(&mut server).unwrap();
        assert_eq!(g.kind, KIND_DOWNLINK);
        assert_eq!(g.round, 7);
        assert_eq!(g.worker, 3);
        assert_eq!(g.residual, 2.5);
        assert_eq!(g.payload, vec![1, 2, 3, 4, 5]);
    }
}
