//! Distributed-deployment surface: checkpointing, the wire protocol types
//! and the TCP socket transport.
//!
//! The threaded parameter-server round loop that used to live here (one
//! master plus `n` OS-thread workers over std mpsc channels — *not* tokio;
//! this offline environment has no tokio crate, and for a
//! barrier-synchronous PS the OS-thread semantics are identical) moved into
//! the round engine as [`crate::engine::Threaded`]. What remains here is
//! deployment machinery:
//!
//! * [`protocol`] — the worker↔master message types (re-exported from
//!   [`crate::engine::protocol`], where the channel transport lives now);
//! * [`tcp`] — [`tcp::TcpTransport`], the same engine over real localhost
//!   sockets with a length-prefixed frame protocol;
//! * [`checkpoint`] — master-model snapshots with integrity checksums.
//!
//! [`run_distributed`] survives as a deprecated shim delegating to
//! [`crate::engine::Session`] with the [`crate::engine::Threaded`]
//! transport; an integration test asserts all transports produce
//! bit-identical iterates.

pub mod checkpoint;
pub mod tcp;

pub use crate::engine::protocol;

use crate::engine::{Session, Threaded, TrainSpec};
use crate::metrics::RunMetrics;
use crate::models::Problem;
use std::sync::Arc;

/// Run a full distributed training job over OS-thread workers and mpsc
/// channels, returning the master's metrics.
#[deprecated(
    note = "use engine::Session::shared(problem).spec(spec).transport(Threaded::new()).run()"
)]
pub fn run_distributed(problem: Arc<dyn Problem>, spec: TrainSpec) -> anyhow::Result<RunMetrics> {
    Session::shared(problem).spec(spec).transport(Threaded::new()).run()
}

/// Alias kept for API symmetry with async runtimes.
#[deprecated(
    note = "use engine::Session::shared(problem).spec(spec).transport(Threaded::new()).run()"
)]
pub fn run_distributed_blocking(
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
) -> anyhow::Result<RunMetrics> {
    Session::shared(problem).spec(spec).transport(Threaded::new()).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;

    /// The deprecated shim must stay bit-identical to the engine it wraps —
    /// and to the in-process path (same state machines, same RNG sites,
    /// real codec in between; encode/decode is exact for every payload).
    #[test]
    #[allow(deprecated)]
    fn run_distributed_shim_matches_inproc_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Sgd, AlgorithmKind::DoubleSqueeze] {
            let spec = TrainSpec { algo, iters: 30, eval_every: 10, ..Default::default() };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = run_distributed(p.clone(), spec).unwrap();
            assert_eq!(a.loss, b.loss, "{} loss mismatch", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
        }
    }

    #[test]
    fn traffic_accounting_close_to_inproc() {
        // wire_bits() (analytic) vs encoded byte lengths (real): equal up
        // to per-message byte padding.
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        let spec =
            TrainSpec { algo: AlgorithmKind::Dore, iters: 10, eval_every: 5, ..Default::default() };
        let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
        let b = Session::shared(p.clone()).spec(spec).transport(Threaded::new()).run().unwrap();
        let tol = |x: u64, y: u64| (x as f64 - y as f64).abs() / (x as f64) < 0.05;
        assert!(tol(a.uplink_bits, b.uplink_bits), "{} vs {}", a.uplink_bits, b.uplink_bits);
        assert!(tol(a.downlink_bits, b.downlink_bits));
    }

    #[test]
    fn many_workers_complete() {
        let p = Arc::new(linreg_problem(120, 12, 12, 0.1, 8));
        let spec =
            TrainSpec { algo: AlgorithmKind::Dore, iters: 15, eval_every: 5, ..Default::default() };
        let m = Session::shared(p).spec(spec).transport(Threaded::new()).run().unwrap();
        assert_eq!(m.total_rounds, 15);
        assert!(m.loss.last().unwrap().is_finite());
    }
}
