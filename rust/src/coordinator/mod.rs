//! Distributed-deployment surface: checkpointing and the layered socket
//! stack (protocol → link → worker/master).
//!
//! The threaded parameter-server round loop that used to live here (one
//! master plus `n` OS-thread workers over std mpsc channels — *not* tokio;
//! this offline environment has no tokio crate, and for a
//! barrier-synchronous PS the OS-thread semantics are identical) moved into
//! the round engine as [`crate::engine::Threaded`]. What remains here is
//! deployment machinery, layered so each module owns one concern:
//!
//! * [`protocol`] — the **one** versioned wire format every byte-moving
//!   transport speaks (re-exported from [`crate::engine::protocol`]):
//!   frame header + kinds, hello/sync/drain bodies, masked downlinks;
//! * `link` (crate-private) — the worker-side socket `WorkerLink` (one
//!   blocking stream per worker process);
//! * [`reactor`] — the master's single readiness-driven event loop: a
//!   hand-rolled epoll poller, slab-keyed connections with reassembly
//!   buffers and buffered nonblocking writes — no per-worker threads;
//! * [`worker`] — the worker side: registration handshake, round schedule,
//!   drain; [`worker::run_remote_worker`] is the `dore-worker` binary's
//!   entry point;
//! * [`tcp`] — [`tcp::TcpTransport`], the master: local worker threads or
//!   an external multi-host fleet (`TcpTransport::bind`), all sockets
//!   multiplexed onto the one reactor;
//! * [`checkpoint`] — master-model snapshots with integrity checksums.
//!
//! The pre-engine `run_distributed(_blocking)` shims were removed once
//! every caller migrated to the builder (`Session::shared(problem)
//! .spec(spec).transport(Threaded::new()).run()` — see the README
//! migration table); the equivalence tests below pin the channel transport
//! against the in-process path directly.

pub mod checkpoint;
pub(crate) mod link;
pub mod reactor;
pub mod tcp;
pub mod worker;

pub use crate::engine::protocol;
pub use worker::run_remote_worker;

#[cfg(test)]
mod tests {
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;
    use crate::engine::{Session, Threaded, TrainSpec};
    use std::sync::Arc;

    /// The channel transport must stay bit-identical to the in-process
    /// path (same state machines, same RNG sites, real codec in between;
    /// encode/decode is exact for every payload).
    #[test]
    fn threaded_transport_matches_inproc_bit_for_bit() {
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Sgd, AlgorithmKind::DoubleSqueeze] {
            let spec = TrainSpec { algo, iters: 30, eval_every: 10, ..Default::default() };
            let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
            let b = Session::shared(p.clone())
                .spec(spec)
                .transport(Threaded::new())
                .run()
                .unwrap();
            assert_eq!(a.loss, b.loss, "{} loss mismatch", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
        }
    }

    #[test]
    fn traffic_accounting_matches_inproc_exactly() {
        // InProc accounts wire_bits_with() on inline payloads; Threaded
        // counts real encoded byte lengths. Both are byte-exact measures of
        // the same frames, so they must agree to the bit, not a tolerance.
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        let spec =
            TrainSpec { algo: AlgorithmKind::Dore, iters: 10, eval_every: 5, ..Default::default() };
        let a = Session::new(p.as_ref()).spec(spec.clone()).run().unwrap();
        let b = Session::shared(p.clone()).spec(spec).transport(Threaded::new()).run().unwrap();
        assert_eq!(a.uplink_bits, b.uplink_bits);
        assert_eq!(a.downlink_bits, b.downlink_bits);
    }

    #[test]
    fn many_workers_complete() {
        let p = Arc::new(linreg_problem(120, 12, 12, 0.1, 8));
        let spec =
            TrainSpec { algo: AlgorithmKind::Dore, iters: 15, eval_every: 5, ..Default::default() };
        let m = Session::shared(p).spec(spec).transport(Threaded::new()).run().unwrap();
        assert_eq!(m.total_rounds, 15);
        assert!(m.loss.last().unwrap().is_finite());
    }
}
