//! Threaded parameter-server runtime.
//!
//! One master thread plus `n` worker threads, connected by std mpsc
//! channels. Payloads cross the channels as **real encoded wire bytes**
//! ([`crate::compression::codec`]), so the byte counts used for
//! communication accounting are the lengths of buffers that actually moved
//! — the same path a TCP deployment would take, minus the socket. (The
//! design brief suggests tokio; this environment is offline and has no
//! tokio crate, so the runtime uses OS threads — for a barrier-synchronous
//! PS with a handful of nodes the semantics and scheduling are identical.)
//!
//! The coordinator drives the identical [`WorkerNode`]/[`MasterNode`] state
//! machines as the in-process harness; an integration test asserts the two
//! paths produce bit-identical iterates.

pub mod checkpoint;
pub mod protocol;
pub mod tcp;

use crate::algorithms::{build, MasterNode, WorkerNode};
use crate::compression::{codec, Xoshiro256};
use crate::harness::TrainSpec;
use crate::metrics::{RunMetrics, Stopwatch};
use crate::models::{linalg, Problem};
use crate::F;
use protocol::{DownlinkMsg, UplinkMsg};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::Arc;

struct WorkerTask {
    id: usize,
    node: Box<dyn WorkerNode>,
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
    to_master: Sender<UplinkMsg>,
    from_master: Receiver<DownlinkMsg>,
}

impl WorkerTask {
    fn run(mut self) -> anyhow::Result<()> {
        let d = self.problem.dim();
        let mut grad = vec![0.0 as F; d];
        for k in 0..self.spec.iters {
            // gradient at the local model copy
            let mut grad_rng =
                Xoshiro256::for_site(self.spec.seed ^ 0x5eed, 1 + self.id as u64, k as u64);
            self.problem.local_grad(
                self.id,
                self.node.model(),
                self.spec.minibatch,
                &mut grad_rng,
                &mut grad,
            );
            let mut qrng = Xoshiro256::for_site(self.spec.seed, 1 + self.id as u64, k as u64);
            let up = self.node.round(k, &grad, &mut qrng);
            let bytes = codec::encode(&up);
            let residual_norm = self.node.last_compressed_norm();
            self.to_master
                .send(UplinkMsg { worker: self.id, round: k, bytes, residual_norm })
                .map_err(|_| anyhow::anyhow!("master hung up"))?;
            let down = self
                .from_master
                .recv()
                .map_err(|_| anyhow::anyhow!("master closed downlink"))?;
            anyhow::ensure!(down.round == k, "round skew: worker {k} got {}", down.round);
            let payload = codec::decode(&down.bytes)?;
            self.node.apply_downlink(k, &payload);
        }
        Ok(())
    }
}

struct MasterTask {
    node: Box<dyn MasterNode>,
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
    from_workers: Receiver<UplinkMsg>,
    to_workers: Vec<SyncSender<DownlinkMsg>>,
}

impl MasterTask {
    fn run(mut self) -> anyhow::Result<RunMetrics> {
        let sw = Stopwatch::start();
        let n = self.to_workers.len();
        let mut metrics = RunMetrics::new(self.spec.algo.name());
        for k in 0..self.spec.iters {
            // barrier gather: one uplink from every worker
            let mut slots: Vec<Option<UplinkMsg>> = (0..n).map(|_| None).collect();
            let mut got = 0;
            while got < n {
                let msg = self
                    .from_workers
                    .recv()
                    .map_err(|_| anyhow::anyhow!("all workers hung up"))?;
                anyhow::ensure!(msg.round == k, "round skew: master {k} got {}", msg.round);
                anyhow::ensure!(slots[msg.worker].is_none(), "duplicate uplink");
                metrics.uplink_bits += msg.bytes.len() as u64 * 8;
                let w = msg.worker;
                slots[w] = Some(msg);
                got += 1;
            }
            let worker_res_norm =
                slots.iter().map(|s| s.as_ref().unwrap().residual_norm).sum::<f64>() / n as f64;
            let uplinks: Vec<_> = slots
                .into_iter()
                .map(|s| codec::decode(&s.unwrap().bytes))
                .collect::<Result<_, _>>()?;
            let mut mrng = Xoshiro256::for_site(self.spec.seed, 0, k as u64);
            let down = self.node.round(k, &uplinks, &mut mrng);
            let bytes = codec::encode(&down);
            metrics.downlink_bits += (bytes.len() as u64 * 8) * n as u64;
            for tx in &self.to_workers {
                tx.send(DownlinkMsg { round: k, bytes: bytes.clone() })
                    .map_err(|_| anyhow::anyhow!("worker hung up"))?;
            }
            if k % self.spec.eval_every == 0 || k + 1 == self.spec.iters {
                let x = self.node.model();
                metrics.rounds.push(k);
                metrics.loss.push(self.problem.loss(x));
                if let Some(xs) = self.problem.optimum() {
                    metrics.dist_to_opt.push(linalg::dist2(x, xs));
                }
                if let Some(tl) = self.problem.test_loss(x) {
                    metrics.test_loss.push(tl);
                }
                if let Some(ta) = self.problem.test_accuracy(x) {
                    metrics.test_acc.push(ta);
                }
                metrics.worker_residual_norm.push(worker_res_norm);
                metrics.master_residual_norm.push(self.node.last_compressed_norm());
            }
        }
        metrics.total_rounds = self.spec.iters;
        metrics.wall_seconds = sw.seconds();
        Ok(metrics)
    }
}

/// Run a full distributed training job: spawns the master on the calling
/// thread and one OS thread per worker, returns the master's metrics.
pub fn run_distributed(problem: Arc<dyn Problem>, spec: TrainSpec) -> anyhow::Result<RunMetrics> {
    let n = problem.n_workers();
    let x0 = problem.init();
    let (workers, master) = build(spec.algo, n, &x0, &spec.hp)?;

    let (up_tx, up_rx) = std::sync::mpsc::channel::<UplinkMsg>();
    let mut down_txs = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for (id, node) in workers.into_iter().enumerate() {
        // depth-1 sync channel: one in-flight round per link, which is all
        // the barrier-synchronous algorithms ever need.
        let (dtx, drx) = std::sync::mpsc::sync_channel::<DownlinkMsg>(1);
        down_txs.push(dtx);
        let task = WorkerTask {
            id,
            node,
            problem: problem.clone(),
            spec: spec.clone(),
            to_master: up_tx.clone(),
            from_master: drx,
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("dore-worker-{id}"))
                .spawn(move || task.run())?,
        );
    }
    drop(up_tx);

    let master_task = MasterTask {
        node: master,
        problem,
        spec,
        from_workers: up_rx,
        to_workers: down_txs,
    };
    let metrics = master_task.run()?;
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
    }
    Ok(metrics)
}

/// Alias kept for API symmetry with async runtimes.
pub fn run_distributed_blocking(
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
) -> anyhow::Result<RunMetrics> {
    run_distributed(problem, spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgorithmKind;
    use crate::data::synth::linreg_problem;
    use crate::harness::run_inproc;

    #[test]
    fn distributed_matches_inproc_bit_for_bit() {
        // The threaded path and the in-proc harness must produce identical
        // iterates: same state machines, same RNG sites, real codec in
        // between (encode/decode is exact for every payload type).
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        for algo in [AlgorithmKind::Dore, AlgorithmKind::Sgd, AlgorithmKind::DoubleSqueeze] {
            let spec = TrainSpec { algo, iters: 30, eval_every: 10, ..Default::default() };
            let a = run_inproc(p.as_ref(), &spec);
            let b = run_distributed(p.clone(), spec).unwrap();
            assert_eq!(a.loss, b.loss, "{} loss mismatch", algo.name());
            assert_eq!(a.dist_to_opt, b.dist_to_opt);
        }
    }

    #[test]
    fn traffic_accounting_close_to_inproc() {
        // wire_bits() (analytic) vs encoded byte lengths (real): equal up
        // to per-message byte padding.
        let p = Arc::new(linreg_problem(60, 16, 3, 0.1, 4));
        let spec =
            TrainSpec { algo: AlgorithmKind::Dore, iters: 10, eval_every: 5, ..Default::default() };
        let a = run_inproc(p.as_ref(), &spec);
        let b = run_distributed(p.clone(), spec).unwrap();
        let tol = |x: u64, y: u64| (x as f64 - y as f64).abs() / (x as f64) < 0.05;
        assert!(tol(a.uplink_bits, b.uplink_bits), "{} vs {}", a.uplink_bits, b.uplink_bits);
        assert!(tol(a.downlink_bits, b.downlink_bits));
    }

    #[test]
    fn many_workers_complete() {
        let p = Arc::new(linreg_problem(120, 12, 12, 0.1, 8));
        let spec =
            TrainSpec { algo: AlgorithmKind::Dore, iters: 15, eval_every: 5, ..Default::default() };
        let m = run_distributed(p, spec).unwrap();
        assert_eq!(m.total_rounds, 15);
        assert!(m.loss.last().unwrap().is_finite());
    }
}
