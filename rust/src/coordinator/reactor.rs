//! A single-threaded readiness reactor for the socket master: one
//! `epoll`-backed [`Poller`] owns every connection, [`Slab`]-allocated
//! per-connection state pairs a zero-copy reassembly buffer ([`RecvBuf`])
//! with a nonblocking write queue ([`SendQueue`]), and [`Reactor`] ties
//! them together behind an event API ([`IoEvent`]). This is what lets one
//! coordinator drive 10,000+ workers without a single per-connection
//! thread — the master's old thread-per-socket reader/writer pairs (see
//! the git history of `coordinator/link.rs`) died at fleet scale.
//!
//! Dependency discipline mirrors `xtask`: no `mio`, no `tokio`, no `libc`
//! crate — the four epoll syscalls are declared by hand, and every other
//! platform falls back to a pure-`std` "all ready" poller that reports
//! every registered connection as readable+writable after a short sleep.
//! Because all I/O here is nonblocking, a spurious-readiness superset is
//! *correct* (reads return `WouldBlock`, writes flush nothing) — it only
//! costs wakeups, and it doubles as a permanent all-spurious-wakeup
//! torture test for the frame reassembly state machines.
//!
//! Determinism: readiness order never feeds the trajectory. The master
//! assembles uplinks into round-keyed slots and closes each round's
//! barrier on a *count* (or, under `fastest:k`, records arrival order as
//! data — the realized mask), so the trained iterates are bit-identical
//! to the threaded and in-process transports. Wall-clock here only bounds
//! waits (each site carries a wall-clock lint allow), exactly like the
//! blocking master it replaced.

use crate::engine::protocol::{parse_frame_header, Frame, FrameHeader, HEADER_BYTES, MAX_PAYLOAD};
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;
// lint:allow(wall_clock, deadlines bound teardown flushes only; never the trajectory)
use std::time::Instant;

#[cfg(unix)]
use std::os::fd::{AsRawFd, RawFd};

/// Fallback fd alias for non-unix targets: the pure-`std` poller never
/// dereferences fds, it only needs the registration calls to typecheck.
#[cfg(not(unix))]
type RawFd = i32;
#[cfg(not(unix))]
trait AsRawFd {
    fn as_raw_fd(&self) -> RawFd {
        0
    }
}
#[cfg(not(unix))]
impl AsRawFd for TcpStream {}
#[cfg(not(unix))]
impl AsRawFd for TcpListener {}

// ---------------------------------------------------------------------------
// Slab: token-stable O(1) storage for per-connection state.
// ---------------------------------------------------------------------------

/// A slab allocator over `Vec<Option<T>>` with a free list: insertion
/// returns a dense `usize` token that stays valid (and is never handed to
/// another entry) until removal, after which the slot is recycled.
/// Deterministic by construction — iteration is index order, tokens are
/// allocated lowest-free-first.
pub struct Slab<T> {
    entries: Vec<Option<T>>,
    free: Vec<usize>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab { entries: Vec::new(), free: Vec::new(), len: 0 }
    }

    /// Insert, returning the entry's token (lowest recycled slot first).
    pub fn insert(&mut self, value: T) -> usize {
        self.len += 1;
        match self.free.pop() {
            Some(key) => {
                debug_assert!(self.entries[key].is_none());
                self.entries[key] = Some(value);
                key
            }
            None => {
                self.entries.push(Some(value));
                self.entries.len() - 1
            }
        }
    }

    pub fn remove(&mut self, key: usize) -> Option<T> {
        let v = self.entries.get_mut(key)?.take()?;
        self.len -= 1;
        self.free.push(key);
        Some(v)
    }

    pub fn get(&self, key: usize) -> Option<&T> {
        self.entries.get(key)?.as_ref()
    }

    pub fn get_mut(&mut self, key: usize) -> Option<&mut T> {
        self.entries.get_mut(key)?.as_mut()
    }

    pub fn contains(&self, key: usize) -> bool {
        self.entries.get(key).is_some_and(|e| e.is_some())
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Live `(token, &entry)` pairs in token order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &T)> {
        self.entries.iter().enumerate().filter_map(|(k, e)| e.as_ref().map(|v| (k, v)))
    }

    /// Live `(token, &mut entry)` pairs in token order.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (usize, &mut T)> {
        self.entries.iter_mut().enumerate().filter_map(|(k, e)| e.as_mut().map(|v| (k, v)))
    }
}

// ---------------------------------------------------------------------------
// Poller: level-triggered readiness over epoll, with a pure-std fallback.
// ---------------------------------------------------------------------------

/// One readiness report. `readable` folds in hangup/error conditions — a
/// read on the fd will resolve them (EOF or a hard error), which is how
/// the reactor discovers dead peers without a separate teardown path.
#[derive(Clone, Copy, Debug)]
pub struct PollEvent {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    //! Hand-declared epoll + rlimit bindings (no `libc` crate in this
    //! container; same zero-dep discipline as `xtask`). Constants and
    //! layouts are the Linux UAPI ones, fixed since 2.6.

    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0x80000;
    pub const RLIMIT_NOFILE: i32 = 7;

    /// `struct epoll_event`. The kernel ABI packs it on x86-64 (12 bytes,
    /// `__EPOLL_PACKED`); other architectures use natural alignment —
    /// mirroring glibc exactly. Fields are only ever read *by value*
    /// (never by reference), which is sound for packed structs.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    #[repr(C)]
    pub struct RLimit {
        pub cur: u64,
        pub max: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32)
            -> i32;
        pub fn close(fd: i32) -> i32;
        pub fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        pub fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
}

/// Level-triggered readiness poller. On Linux this is one epoll instance
/// (O(ready) wakeups — the property that makes a 10k-connection master's
/// per-wake work independent of fleet size); elsewhere it is a pure-`std`
/// all-ready superset poller (see the module docs for why that is
/// correct, if busier).
#[cfg(target_os = "linux")]
pub struct Poller {
    epfd: i32,
    /// Scratch buffer reused across `wait` calls.
    events: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> anyhow::Result<Poller> {
        // SAFETY: epoll_create1 takes no pointers; the returned fd is
        // owned by this Poller and closed exactly once, in Drop.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        anyhow::ensure!(epfd >= 0, "epoll_create1 failed: {}", std::io::Error::last_os_error());
        Ok(Poller { epfd, events: Vec::with_capacity(1024) })
    }

    fn interest(writable: bool) -> u32 {
        let mut ev = sys::EPOLLIN | sys::EPOLLRDHUP;
        if writable {
            ev |= sys::EPOLLOUT;
        }
        ev
    }

    fn ctl(&mut self, op: i32, fd: RawFd, token: usize, writable: bool) -> anyhow::Result<()> {
        let mut ev = sys::EpollEvent { events: Self::interest(writable), data: token as u64 };
        // SAFETY: `ev` outlives the call; the kernel copies it before
        // returning. `fd` is a live socket owned by the caller.
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        anyhow::ensure!(
            rc == 0,
            "epoll_ctl(op {op}, fd {fd}) failed: {}",
            std::io::Error::last_os_error()
        );
        Ok(())
    }

    /// Start watching `fd` under `token`; `writable` arms EPOLLOUT too.
    pub fn register(&mut self, fd: RawFd, token: usize, writable: bool) -> anyhow::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, writable)
    }

    /// Re-arm an already-registered fd (toggle write interest).
    pub fn rearm(&mut self, fd: RawFd, token: usize, writable: bool) -> anyhow::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, writable)
    }

    pub fn deregister(&mut self, fd: RawFd) -> anyhow::Result<()> {
        let mut ev = sys::EpollEvent { events: 0, data: 0 };
        // SAFETY: pre-2.6.9 kernels required a non-null event pointer for
        // EPOLL_CTL_DEL; passing one is harmless everywhere else.
        let rc = unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, &mut ev) };
        anyhow::ensure!(
            rc == 0,
            "epoll_ctl(DEL, fd {fd}) failed: {}",
            std::io::Error::last_os_error()
        );
        Ok(())
    }

    /// Wait up to `timeout` and append readiness reports to `out`.
    pub fn wait(&mut self, timeout: Duration, out: &mut Vec<PollEvent>) -> anyhow::Result<()> {
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        self.events.clear();
        let cap = self.events.capacity().max(64) as i32;
        loop {
            // SAFETY: the pointer/len pair is the scratch Vec's spare
            // capacity; `n` entries are initialized by the kernel before
            // set_len, and n ≤ cap ≤ capacity.
            let n = unsafe {
                let n = sys::epoll_wait(self.epfd, self.events.as_mut_ptr(), cap, ms);
                if n > 0 {
                    self.events.set_len(n as usize);
                }
                n
            };
            if n >= 0 {
                break;
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != ErrorKind::Interrupted {
                anyhow::bail!("epoll_wait failed: {err}");
            }
        }
        for ev in &self.events {
            // copy packed fields by value (never by reference)
            let bits = { *ev }.events;
            let token = { *ev }.data as usize;
            out.push(PollEvent {
                token,
                readable: bits & (sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLHUP | sys::EPOLLERR)
                    != 0,
                writable: bits & (sys::EPOLLOUT | sys::EPOLLHUP | sys::EPOLLERR) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: epfd was returned by epoll_create1 and closed only here.
        unsafe {
            sys::close(self.epfd);
        }
    }
}

/// Pure-`std` fallback poller for non-Linux targets: after a short sleep,
/// report every registered fd as readable and writable. A strict superset
/// of true readiness — correct because all reactor I/O is nonblocking.
#[cfg(not(target_os = "linux"))]
pub struct Poller {
    /// `(token, writable)` in registration order.
    registered: Vec<(usize, bool)>,
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    pub fn new() -> anyhow::Result<Poller> {
        Ok(Poller { registered: Vec::new() })
    }

    pub fn register(&mut self, _fd: RawFd, token: usize, writable: bool) -> anyhow::Result<()> {
        self.registered.push((token, writable));
        Ok(())
    }

    pub fn rearm(&mut self, _fd: RawFd, token: usize, writable: bool) -> anyhow::Result<()> {
        for e in self.registered.iter_mut() {
            if e.0 == token {
                e.1 = writable;
            }
        }
        Ok(())
    }

    pub fn deregister_token(&mut self, token: usize) {
        self.registered.retain(|e| e.0 != token);
    }

    pub fn wait(&mut self, timeout: Duration, out: &mut Vec<PollEvent>) -> anyhow::Result<()> {
        std::thread::sleep(timeout.min(Duration::from_micros(500)));
        for &(token, writable) in &self.registered {
            out.push(PollEvent { token, readable: true, writable });
        }
        Ok(())
    }
}

/// Raise `RLIMIT_NOFILE` toward `want` file descriptors (best effort,
/// Linux only) and return the resulting soft limit. The 10k-connection
/// smoke calls this first and clamps its fleet to what it actually got.
#[cfg(target_os = "linux")]
pub fn raise_nofile_limit(want: u64) -> u64 {
    let mut rl = sys::RLimit { cur: 0, max: 0 };
    // SAFETY: getrlimit writes the two-word struct we pass; setrlimit
    // reads the one we pass; neither retains the pointer.
    unsafe {
        if sys::getrlimit(sys::RLIMIT_NOFILE, &mut rl) != 0 {
            return 1024;
        }
        if rl.cur >= want {
            return rl.cur;
        }
        let raised = sys::RLimit { cur: want.max(rl.cur), max: rl.max.max(want) };
        if sys::setrlimit(sys::RLIMIT_NOFILE, &raised) == 0 {
            return raised.cur;
        }
        // raising the hard limit needs privilege; settle for the hard cap
        let capped = sys::RLimit { cur: rl.max, max: rl.max };
        if sys::setrlimit(sys::RLIMIT_NOFILE, &capped) == 0 {
            return rl.max;
        }
        rl.cur
    }
}

#[cfg(not(target_os = "linux"))]
pub fn raise_nofile_limit(_want: u64) -> u64 {
    1024
}

// ---------------------------------------------------------------------------
// RecvBuf: zero-copy frame reassembly off a nonblocking stream.
// ---------------------------------------------------------------------------

/// Outcome of one [`RecvBuf::try_frame`] attempt.
pub enum RecvStep {
    /// A complete frame was assembled.
    Frame(Frame),
    /// The peer has nothing more to say right now.
    WouldBlock,
    /// EOF / reset / broken pipe — the connection-fault path.
    Closed,
}

enum RecvState {
    /// Accumulating the fixed 24 header bytes.
    Header { buf: [u8; HEADER_BYTES], have: usize },
    /// Header parsed; reading `payload_len` bytes **directly into the
    /// buffer the frame hands to its decoder** — no intermediate
    /// reassembly `Vec`, no post-hoc payload copy.
    Payload { head: FrameHeader, buf: Vec<u8>, have: usize },
}

/// Per-connection reassembly state machine: feeds itself from a
/// nonblocking `Read` in whatever chunk sizes the kernel delivers, and
/// yields complete frames. Replaces the old grow-only `Vec` +
/// `take_frame` pair — the payload is read once, into its final buffer.
pub struct RecvBuf {
    state: RecvState,
    /// Per-connection payload cap. Pre-registration connections get a
    /// small cap so an unauthenticated peer cannot demand a 1 GiB
    /// allocation with a forged length field; the cap is lifted to
    /// [`MAX_PAYLOAD`] once the hello validates.
    cap: usize,
}

impl RecvBuf {
    pub fn new(cap: usize) -> Self {
        RecvBuf { state: RecvState::Header { buf: [0; HEADER_BYTES], have: 0 }, cap }
    }

    /// Lift (or lower) the payload cap — e.g. after a validated hello.
    pub fn set_cap(&mut self, cap: usize) {
        self.cap = cap.min(MAX_PAYLOAD);
    }

    /// Pull bytes from `r` until a frame completes, the stream would
    /// block, or the peer is gone. Protocol errors (bad magic, version
    /// skew, an over-cap length) surface as `Err`.
    pub fn try_frame<R: Read>(&mut self, r: &mut R) -> anyhow::Result<RecvStep> {
        let cap = self.cap;
        loop {
            match &mut self.state {
                RecvState::Header { buf, have } => {
                    match r.read(&mut buf[*have..]) {
                        Ok(0) => return Ok(RecvStep::Closed),
                        Ok(k) => *have += k,
                        Err(e) => match Self::classify(e)? {
                            Some(step) => return Ok(step),
                            None => continue,
                        },
                    }
                    if *have < HEADER_BYTES {
                        continue;
                    }
                    let head = parse_frame_header(buf)?;
                    anyhow::ensure!(
                        head.payload_len <= cap,
                        "frame payload length {} exceeds this connection's {}-byte receive \
                         cap (unregistered peers may only send hellos)",
                        head.payload_len,
                        cap
                    );
                    if head.payload_len == 0 {
                        self.state = RecvState::Header { buf: [0; HEADER_BYTES], have: 0 };
                        return Ok(RecvStep::Frame(Self::complete(head, Vec::new())));
                    }
                    self.state =
                        RecvState::Payload { head, buf: vec![0u8; head.payload_len], have: 0 };
                }
                RecvState::Payload { head, buf, have } => {
                    match r.read(&mut buf[*have..]) {
                        Ok(0) => return Ok(RecvStep::Closed),
                        Ok(k) => *have += k,
                        Err(e) => match Self::classify(e)? {
                            Some(step) => return Ok(step),
                            None => continue,
                        },
                    }
                    if *have < buf.len() {
                        continue;
                    }
                    let head = *head;
                    let payload = std::mem::take(buf);
                    self.state = RecvState::Header { buf: [0; HEADER_BYTES], have: 0 };
                    return Ok(RecvStep::Frame(Self::complete(head, payload)));
                }
            }
        }
    }

    /// Blocking companion for drain/handshake paths: the caller bounds the
    /// wait with `set_read_timeout` on the (blocking-mode) socket.
    pub fn read_frame_blocking<R: Read>(&mut self, r: &mut R) -> anyhow::Result<Frame> {
        loop {
            match self.try_frame(r)? {
                RecvStep::Frame(f) => return Ok(f),
                // a blocking socket only reports WouldBlock on timeout
                RecvStep::WouldBlock => anyhow::bail!("timed out waiting for a frame"),
                RecvStep::Closed => anyhow::bail!("connection closed mid-frame"),
            }
        }
    }

    /// Map an I/O error to a step (`Some`), a retry (`None`), or a real
    /// error. EOF-ish conditions are `Closed` — the fault path, not a
    /// failure of the master.
    fn classify(e: std::io::Error) -> anyhow::Result<Option<RecvStep>> {
        match e.kind() {
            ErrorKind::WouldBlock | ErrorKind::TimedOut => Ok(Some(RecvStep::WouldBlock)),
            ErrorKind::Interrupted => Ok(None),
            ErrorKind::ConnectionReset | ErrorKind::ConnectionAborted | ErrorKind::BrokenPipe => {
                Ok(Some(RecvStep::Closed))
            }
            _ => Err(e.into()),
        }
    }

    fn complete(head: FrameHeader, payload: Vec<u8>) -> Frame {
        Frame {
            kind: head.kind,
            round: head.round,
            worker: head.worker,
            residual: head.residual,
            payload,
        }
    }
}

// ---------------------------------------------------------------------------
// SendQueue: nonblocking buffered writes, shared broadcast payloads.
// ---------------------------------------------------------------------------

/// A queued frame's payload: owned bytes for per-peer frames (sync
/// replies, rejections), or a refcounted slice for broadcasts — one
/// downlink payload is shared by every connection's queue instead of
/// being cloned `n` times.
#[derive(Clone)]
pub enum SendPayload {
    Owned(Vec<u8>),
    Shared(Arc<[u8]>),
}

impl SendPayload {
    fn as_slice(&self) -> &[u8] {
        match self {
            SendPayload::Owned(v) => v,
            SendPayload::Shared(a) => a,
        }
    }
}

struct QueuedFrame {
    header: [u8; HEADER_BYTES],
    payload: SendPayload,
    /// Write progress across header + payload.
    off: usize,
}

/// Outcome of one [`SendQueue::flush`] attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushStatus {
    /// Everything queued has hit the socket.
    Clean,
    /// The socket would block; re-flush on the next writability report.
    Pending,
    /// The peer is gone (any write error — the fault path).
    Closed,
}

/// Per-connection nonblocking write queue: frames are queued as a header
/// array plus a payload slice (the writev split — the payload is written
/// straight from its original, possibly shared, buffer) and drained
/// whenever the socket reports writable. Replaces the per-connection
/// downlink writer thread.
#[derive(Default)]
pub struct SendQueue {
    q: VecDeque<QueuedFrame>,
    buffered: usize,
}

impl SendQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_frame(&mut self, header: [u8; HEADER_BYTES], payload: SendPayload) {
        self.buffered += HEADER_BYTES + payload.as_slice().len();
        self.q.push_back(QueuedFrame { header, payload, off: 0 });
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Bytes queued but not yet written.
    pub fn buffered_bytes(&self) -> usize {
        self.buffered
    }

    /// Write as much queued data as the socket accepts right now.
    pub fn flush<W: Write>(&mut self, w: &mut W) -> FlushStatus {
        while let Some(front) = self.q.front_mut() {
            let payload = front.payload.as_slice();
            let total = HEADER_BYTES + payload.len();
            while front.off < total {
                let res = if front.off < HEADER_BYTES {
                    w.write(&front.header[front.off..])
                } else {
                    w.write(&payload[front.off - HEADER_BYTES..])
                };
                match res {
                    Ok(0) => return FlushStatus::Closed,
                    Ok(k) => {
                        front.off += k;
                        self.buffered -= k;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return FlushStatus::Pending,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    // reset / broken pipe / anything else: the peer is
                    // gone — an expected fault, mirrored on the old
                    // writer thread's broken-pipe exit
                    Err(_) => return FlushStatus::Closed,
                }
            }
            self.q.pop_front();
        }
        FlushStatus::Clean
    }
}

// ---------------------------------------------------------------------------
// Reactor: poller + slab of connections + the event API.
// ---------------------------------------------------------------------------

/// The listener's reserved token (connections use slab tokens, which are
/// dense and can never reach this value).
pub const LISTENER_TOKEN: usize = usize::MAX;

/// Default pre-registration payload cap: hellos are 16 bytes; anything
/// claiming more before it authenticates is hostile or lost.
pub const PRE_HELLO_CAP: usize = 4096;

struct Conn {
    sock: TcpStream,
    recv: RecvBuf,
    send: SendQueue,
    /// EPOLLOUT currently armed.
    want_write: bool,
    /// Close once the send queue drains (stop reading meanwhile) — used
    /// for rejection replies that should reach the peer before the drop.
    closing: bool,
}

/// One I/O cycle's observations, in readiness order.
pub enum IoEvent {
    /// The listener produced a new connection (already registered, under
    /// the returned token, with the pre-hello receive cap).
    Accepted(usize),
    /// A complete frame arrived on `token`.
    Frame { token: usize, frame: Frame },
    /// The peer on `token` is gone (EOF/reset, or a send hit a dead
    /// socket). The connection has already been dropped.
    Closed(usize),
    /// The peer on `token` violated the protocol (bad magic, version
    /// skew, over-cap length). The connection has already been dropped;
    /// the caller decides whether that fails the run.
    Bad { token: usize, error: anyhow::Error },
}

/// One readiness-driven event loop owning every master-side socket: the
/// listener plus a [`Slab`] of connections, each pairing a [`RecvBuf`]
/// with a [`SendQueue`]. All sockets are nonblocking; `poll_io` turns
/// readiness into [`IoEvent`]s and opportunistically drains write queues.
/// Protocol semantics (what a frame *means*) stay with the caller.
pub struct Reactor {
    poller: Poller,
    conns: Slab<Conn>,
    listener: Option<TcpListener>,
    /// Scratch readiness buffer reused across polls.
    scratch: Vec<PollEvent>,
}

impl Reactor {
    pub fn new() -> anyhow::Result<Self> {
        Ok(Reactor {
            poller: Poller::new()?,
            conns: Slab::new(),
            listener: None,
            scratch: Vec::new(),
        })
    }

    /// Adopt (and register) the accept listener.
    pub fn listen(&mut self, listener: TcpListener) -> anyhow::Result<()> {
        listener.set_nonblocking(true)?;
        self.poller.register(listener.as_raw_fd(), LISTENER_TOKEN, false)?;
        self.listener = Some(listener);
        Ok(())
    }

    /// Stop accepting: deregister and drop the listener. Reconnects are
    /// refused from here on — the teardown barrier.
    pub fn unlisten(&mut self) {
        if let Some(l) = self.listener.take() {
            #[cfg(target_os = "linux")]
            let _ = self.poller.deregister(l.as_raw_fd());
            #[cfg(not(target_os = "linux"))]
            self.poller.deregister_token(LISTENER_TOKEN);
            drop(l);
        }
    }

    /// Adopt an established socket: nonblocking, nodelay, registered for
    /// reads, pre-hello receive cap. Returns its token.
    pub fn add(&mut self, sock: TcpStream) -> anyhow::Result<usize> {
        sock.set_nodelay(true)?;
        sock.set_nonblocking(true)?;
        let fd = sock.as_raw_fd();
        let token = self.conns.insert(Conn {
            sock,
            recv: RecvBuf::new(PRE_HELLO_CAP),
            send: SendQueue::new(),
            want_write: false,
            closing: false,
        });
        if let Err(e) = self.poller.register(fd, token, false) {
            self.conns.remove(token);
            return Err(e);
        }
        Ok(token)
    }

    pub fn is_open(&self, token: usize) -> bool {
        self.conns.contains(token)
    }

    /// Live connection count (the listener is not a connection).
    pub fn len(&self) -> usize {
        self.conns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.conns.is_empty()
    }

    /// Lift the receive cap after a validated hello.
    pub fn set_recv_cap(&mut self, token: usize, cap: usize) {
        if let Some(c) = self.conns.get_mut(token) {
            c.recv.set_cap(cap);
        }
    }

    /// Queue a frame and eagerly flush. Returns `Ok(false)` if the
    /// connection is absent or the peer died on the spot (the connection
    /// is dropped); the caller owns the consequences.
    pub fn send_frame(
        &mut self,
        token: usize,
        header: [u8; HEADER_BYTES],
        payload: SendPayload,
    ) -> anyhow::Result<bool> {
        let Some(conn) = self.conns.get_mut(token) else { return Ok(false) };
        conn.send.push_frame(header, payload);
        match conn.send.flush(&mut conn.sock) {
            FlushStatus::Clean => {
                if conn.want_write {
                    conn.want_write = false;
                    let fd = conn.sock.as_raw_fd();
                    self.poller.rearm(fd, token, false)?;
                }
                Ok(true)
            }
            FlushStatus::Pending => {
                if !conn.want_write {
                    conn.want_write = true;
                    let fd = conn.sock.as_raw_fd();
                    self.poller.rearm(fd, token, true)?;
                }
                Ok(true)
            }
            FlushStatus::Closed => {
                self.drop_conn(token);
                Ok(false)
            }
        }
    }

    /// Unfinished bytes queued for `token` (0 if absent).
    pub fn pending_bytes(&self, token: usize) -> usize {
        self.conns.get(token).map_or(0, |c| c.send.buffered_bytes())
    }

    /// Drop a connection immediately (deregister + close).
    pub fn close(&mut self, token: usize) {
        self.drop_conn(token);
    }

    /// Close once the send queue drains (the connection stops being read
    /// either way). Closes immediately if nothing is queued.
    pub fn close_after_flush(&mut self, token: usize) {
        let empty = match self.conns.get(token) {
            Some(c) => c.send.is_empty(),
            None => return,
        };
        if empty {
            self.drop_conn(token);
        } else if let Some(c) = self.conns.get_mut(token) {
            c.closing = true;
        }
    }

    /// Detach a connection from the loop and hand back its socket plus
    /// reassembly state (bytes already buffered mid-frame are preserved)
    /// — the blocking-drain escape hatch for teardown paths.
    pub fn detach(&mut self, token: usize) -> Option<(TcpStream, RecvBuf)> {
        let conn = self.conns.remove(token)?;
        #[cfg(target_os = "linux")]
        let _ = self.poller.deregister(conn.sock.as_raw_fd());
        #[cfg(not(target_os = "linux"))]
        self.poller.deregister_token(token);
        Some((conn.sock, conn.recv))
    }

    fn drop_conn(&mut self, token: usize) {
        if let Some(conn) = self.conns.remove(token) {
            #[cfg(target_os = "linux")]
            let _ = self.poller.deregister(conn.sock.as_raw_fd());
            #[cfg(not(target_os = "linux"))]
            self.poller.deregister_token(token);
            drop(conn);
        }
    }

    /// One reactor cycle: wait up to `timeout`, accept anything pending,
    /// drain every readable connection into frames, flush every writable
    /// send queue. Events append to `sink` in readiness order.
    pub fn poll_io(&mut self, timeout: Duration, sink: &mut Vec<IoEvent>) -> anyhow::Result<()> {
        let mut ready = std::mem::take(&mut self.scratch);
        ready.clear();
        let wait = self.poller.wait(timeout, &mut ready);
        let step = wait.and_then(|()| {
            for ev in &ready {
                if ev.token == LISTENER_TOKEN {
                    self.accept_ready(sink)?;
                } else {
                    self.conn_ready(ev.token, ev.readable, ev.writable, sink)?;
                }
            }
            Ok(())
        });
        self.scratch = ready;
        step
    }

    fn accept_ready(&mut self, sink: &mut Vec<IoEvent>) -> anyhow::Result<()> {
        loop {
            let Some(listener) = &self.listener else { return Ok(()) };
            match listener.accept() {
                Ok((sock, _)) => {
                    let token = self.add(sock)?;
                    sink.push(IoEvent::Accepted(token));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                // a connection that reset between SYN and accept is
                // nobody we ever met — skip it
                Err(e) if e.kind() == ErrorKind::ConnectionReset => continue,
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn conn_ready(
        &mut self,
        token: usize,
        readable: bool,
        writable: bool,
        sink: &mut Vec<IoEvent>,
    ) -> anyhow::Result<()> {
        // (the connection may have been dropped earlier in this batch)
        let Some(conn) = self.conns.get_mut(token) else { return Ok(()) };
        if readable && !conn.closing {
            loop {
                match conn.recv.try_frame(&mut conn.sock) {
                    Ok(RecvStep::Frame(frame)) => sink.push(IoEvent::Frame { token, frame }),
                    Ok(RecvStep::WouldBlock) => break,
                    Ok(RecvStep::Closed) => {
                        self.drop_conn(token);
                        sink.push(IoEvent::Closed(token));
                        return Ok(());
                    }
                    Err(error) => {
                        self.drop_conn(token);
                        sink.push(IoEvent::Bad { token, error });
                        return Ok(());
                    }
                }
            }
        }
        let Some(conn) = self.conns.get_mut(token) else { return Ok(()) };
        if writable && !conn.send.is_empty() {
            match conn.send.flush(&mut conn.sock) {
                FlushStatus::Clean => {
                    if conn.closing {
                        self.drop_conn(token);
                        return Ok(());
                    }
                    if conn.want_write {
                        conn.want_write = false;
                        let fd = conn.sock.as_raw_fd();
                        self.poller.rearm(fd, token, false)?;
                    }
                }
                FlushStatus::Pending => {
                    if !conn.want_write {
                        conn.want_write = true;
                        let fd = conn.sock.as_raw_fd();
                        self.poller.rearm(fd, token, true)?;
                    }
                }
                FlushStatus::Closed => {
                    let was_closing = conn.closing;
                    self.drop_conn(token);
                    if !was_closing {
                        sink.push(IoEvent::Closed(token));
                    }
                }
            }
        } else if writable && conn.closing {
            self.drop_conn(token);
        }
        Ok(())
    }

    /// Drive the loop until every send queue is clean or `deadline`
    /// passes; frames read meanwhile (early drain digests, stray
    /// speculative uplinks) still land in `sink`. Returns the tokens
    /// whose queues were still dirty at the deadline — the bounded
    /// replacement for the old flush-and-join teardown that could hang
    /// `finish()` forever on a peer that stopped reading.
    #[allow(clippy::disallowed_methods)] // wall-clock: teardown flush deadline only
    pub fn flush_all(
        &mut self,
        // lint:allow(wall_clock, bounded teardown flush; never feeds the trajectory)
        deadline: Instant,
        sink: &mut Vec<IoEvent>,
    ) -> anyhow::Result<Vec<usize>> {
        loop {
            let dirty: Vec<usize> = self
                .conns
                .iter()
                .filter(|(_, c)| !c.send.is_empty())
                .map(|(t, _)| t)
                .collect();
            if dirty.is_empty() {
                return Ok(Vec::new());
            }
            // lint:allow(wall_clock, teardown flush deadline check)
            if Instant::now() >= deadline {
                return Ok(dirty);
            }
            self.poll_io(Duration::from_millis(5), sink)?;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::protocol::{frame_header, take_frame, FrameKind};
    use std::io::Cursor;

    fn mk_frame(round: u32, len: usize) -> Frame {
        Frame {
            kind: FrameKind::Uplink,
            round,
            worker: 1,
            residual: 0.5,
            payload: (0..len).map(|i| (i % 251) as u8).collect(),
        }
    }

    #[test]
    fn slab_reuses_slots_and_keeps_keys_stable() {
        let mut s: Slab<&'static str> = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        let c = s.insert("c");
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(s.remove(b), Some("b"));
        assert_eq!(s.len(), 2);
        assert!(!s.contains(b));
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.get(c), Some(&"c"));
        // freed slot is recycled; existing keys untouched
        let d = s.insert("d");
        assert_eq!(d, b);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.remove(99), None);
        let keys: Vec<usize> = s.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec![0, 1, 2]);
    }

    /// A `Read` that delivers a byte stream in scripted chunk sizes, with
    /// `0`-sized script entries meaning "WouldBlock here" (a spurious
    /// wakeup as seen by the reassembly machine).
    struct ChunkReader {
        data: Vec<u8>,
        pos: usize,
        script: Vec<usize>,
        step: usize,
    }

    impl Read for ChunkReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            let want = self.script.get(self.step).copied().unwrap_or(usize::MAX);
            self.step += 1;
            if want == 0 {
                return Err(std::io::Error::from(ErrorKind::WouldBlock));
            }
            let k = want.min(out.len()).min(self.data.len() - self.pos);
            if k == 0 {
                return Ok(0); // EOF
            }
            out[..k].copy_from_slice(&self.data[self.pos..self.pos + k]);
            self.pos += k;
            Ok(k)
        }
    }

    #[test]
    fn recvbuf_reassembles_across_pathological_chunking() {
        let frames = [mk_frame(0, 0), mk_frame(1, 5), mk_frame(2, 100)];
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(&f.to_bytes());
        }
        // byte-at-a-time with a WouldBlock between every byte
        let script: Vec<usize> = (0..wire.len() * 2).map(|i| i % 2).collect();
        let mut r = ChunkReader { data: wire, pos: 0, script, step: 0 };
        let mut rb = RecvBuf::new(MAX_PAYLOAD);
        let mut got = Vec::new();
        loop {
            match rb.try_frame(&mut r).unwrap() {
                RecvStep::Frame(f) => got.push(f),
                RecvStep::WouldBlock => continue,
                RecvStep::Closed => break,
            }
        }
        assert_eq!(got, frames);
    }

    #[test]
    fn recvbuf_enforces_its_payload_cap() {
        let f = mk_frame(0, 64);
        let mut r = Cursor::new(f.to_bytes());
        let mut rb = RecvBuf::new(16);
        let err = rb.try_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("receive cap"), "{err}");
        // and a lifted cap admits the same frame
        let mut r = Cursor::new(f.to_bytes());
        let mut rb = RecvBuf::new(16);
        rb.set_cap(MAX_PAYLOAD);
        match rb.try_frame(&mut r).unwrap() {
            RecvStep::Frame(g) => assert_eq!(g, f),
            _ => panic!("frame expected"),
        }
    }

    /// A `Write` that accepts at most a scripted number of bytes per
    /// call, interleaving WouldBlock (0 in the script).
    struct TrickleWriter {
        out: Vec<u8>,
        script: Vec<usize>,
        step: usize,
    }

    impl Write for TrickleWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let want = self.script.get(self.step).copied().unwrap_or(usize::MAX);
            self.step += 1;
            if want == 0 {
                return Err(std::io::Error::from(ErrorKind::WouldBlock));
            }
            let k = want.min(buf.len());
            self.out.extend_from_slice(&buf[..k]);
            Ok(k)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn sendqueue_partial_writes_produce_an_intact_stream() {
        let broadcast: Arc<[u8]> = vec![7u8; 53].into();
        let mut q = SendQueue::new();
        q.push_frame(
            frame_header(FrameKind::Downlink, 3, 0, 0.0, broadcast.len()),
            SendPayload::Shared(broadcast.clone()),
        );
        q.push_frame(
            frame_header(FrameKind::Sync, 0, 2, 0.0, 4),
            SendPayload::Owned(vec![1, 2, 3, 4]),
        );
        let total = q.buffered_bytes();
        assert_eq!(total, 2 * HEADER_BYTES + 53 + 4);
        // dribble 3 bytes per accepted write, WouldBlock every other call
        let mut w = TrickleWriter {
            out: Vec::new(),
            script: (0..1000).map(|i| if i % 2 == 0 { 0 } else { 3 }).collect(),
            step: 0,
        };
        let mut pending = 0;
        loop {
            match q.flush(&mut w) {
                FlushStatus::Clean => break,
                FlushStatus::Pending => pending += 1,
                FlushStatus::Closed => panic!("writer never closes"),
            }
        }
        assert!(pending > 0, "the trickle writer must have exercised Pending");
        assert_eq!(q.buffered_bytes(), 0);
        // the byte stream re-frames into exactly the two queued frames
        let mut buf = w.out;
        let f1 = take_frame(&mut buf).unwrap().unwrap();
        assert_eq!((f1.kind, f1.round, f1.payload.len()), (FrameKind::Downlink, 3, 53));
        assert_eq!(f1.payload, &broadcast[..]);
        let f2 = take_frame(&mut buf).unwrap().unwrap();
        assert_eq!((f2.kind, f2.worker, f2.payload), (FrameKind::Sync, 2, vec![1, 2, 3, 4]));
        assert!(buf.is_empty());
    }

    #[test]
    fn reactor_accepts_frames_and_replies_over_real_sockets() {
        use crate::engine::protocol::{read_frame, write_frame};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut reactor = Reactor::new().unwrap();
        reactor.listen(listener).unwrap();

        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            write_frame(&mut s, &mk_frame(0, 16)).unwrap();
            // wait for the reactor's reply
            let reply = read_frame(&mut s).unwrap();
            (reply.kind, reply.payload)
        });

        let mut sink = Vec::new();
        let mut token = None;
        let mut got_frame = None;
        for _ in 0..2000 {
            reactor.poll_io(Duration::from_millis(5), &mut sink).unwrap();
            for ev in sink.drain(..) {
                match ev {
                    IoEvent::Accepted(t) => token = Some(t),
                    IoEvent::Frame { token: t, frame } => {
                        assert_eq!(Some(t), token);
                        got_frame = Some(frame);
                    }
                    IoEvent::Closed(_) => {}
                    IoEvent::Bad { error, .. } => panic!("bad: {error}"),
                }
            }
            if got_frame.is_some() {
                break;
            }
        }
        let f = got_frame.expect("frame received");
        assert_eq!(f, mk_frame(0, 16));
        let t = token.unwrap();
        let ok = reactor
            .send_frame(
                t,
                frame_header(FrameKind::Sync, 0, 0, 0.0, 3),
                SendPayload::Owned(vec![9, 9, 9]),
            )
            .unwrap();
        assert!(ok);
        // drain the queue (eager flush almost certainly already did)
        // lint:allow(wall_clock, test deadline)
        let deadline = Instant::now() + Duration::from_secs(5);
        reactor.flush_all(deadline, &mut sink).unwrap();
        let (kind, payload) = client.join().unwrap();
        assert_eq!(kind, FrameKind::Sync);
        assert_eq!(payload, vec![9, 9, 9]);
    }

    #[test]
    fn reactor_reports_bad_peers_and_closed_peers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut reactor = Reactor::new().unwrap();
        reactor.listen(listener).unwrap();
        // peer 1: garbage bytes; peer 2: clean immediate close
        let garbage = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"XX not the dore protocol XX").unwrap();
            s
        });
        let closer = std::thread::spawn(move || {
            let s = TcpStream::connect(addr).unwrap();
            drop(s);
        });
        let mut sink = Vec::new();
        let (mut bads, mut closes, mut accepts) = (0, 0, 0);
        for _ in 0..2000 {
            reactor.poll_io(Duration::from_millis(5), &mut sink).unwrap();
            for ev in sink.drain(..) {
                match ev {
                    IoEvent::Accepted(_) => accepts += 1,
                    IoEvent::Bad { error, .. } => {
                        assert!(error.to_string().contains("magic"), "{error}");
                        bads += 1;
                    }
                    IoEvent::Closed(_) => closes += 1,
                    IoEvent::Frame { .. } => panic!("no valid frames were sent"),
                }
            }
            if bads == 1 && closes == 1 {
                break;
            }
        }
        assert_eq!((accepts, bads, closes), (2, 1, 1));
        assert!(reactor.is_empty(), "both peers must have been dropped");
        drop(garbage.join().unwrap());
        closer.join().unwrap();
    }

    #[test]
    fn close_after_flush_delivers_the_rejection_first() {
        use crate::engine::protocol::read_frame;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut reactor = Reactor::new().unwrap();
        reactor.listen(listener).unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let reply = read_frame(&mut s).unwrap();
            // after the reply the master hangs up
            let mut rest = Vec::new();
            let _ = s.read_to_end(&mut rest);
            (reply, rest)
        });
        let mut sink = Vec::new();
        let mut token = None;
        while token.is_none() {
            reactor.poll_io(Duration::from_millis(5), &mut sink).unwrap();
            for ev in sink.drain(..) {
                if let IoEvent::Accepted(t) = ev {
                    token = Some(t);
                }
            }
        }
        let t = token.unwrap();
        reactor
            .send_frame(
                t,
                frame_header(FrameKind::Drain, 0, 0, 0.0, 2),
                SendPayload::Owned(vec![4, 2]),
            )
            .unwrap();
        reactor.close_after_flush(t);
        // lint:allow(wall_clock, test deadline)
        let deadline = Instant::now() + Duration::from_secs(5);
        while reactor.is_open(t) {
            // lint:allow(wall_clock, test deadline)
            assert!(Instant::now() < deadline, "close_after_flush never closed");
            reactor.poll_io(Duration::from_millis(5), &mut sink).unwrap();
            sink.clear();
        }
        let (reply, rest) = client.join().unwrap();
        assert_eq!(reply.kind, FrameKind::Drain);
        assert_eq!(reply.payload, vec![4, 2]);
        assert!(rest.is_empty(), "EOF follows the flushed reply");
    }
}
