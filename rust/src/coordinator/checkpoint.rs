//! Checkpointing: serialize / restore a training run's full state (round
//! counter, master iterate, fleet size, every node's aux vectors) so long
//! jobs can resume after preemption. Wired into the round engine through
//! [`crate::engine::Session::checkpoint_every`] /
//! [`crate::engine::Session::resume_from`].
//!
//! Format (little-endian): magic, version, checksum, then the body —
//! algo name, round, seed, worker count, the master's model vector, and
//! the named aux vectors (`m.*` for the master, `w<i>.*` per worker).
//! Because every stochastic site is keyed by `(seed, node, round)`,
//! resuming from `(round, model, aux)` with the same seed reproduces the
//! exact trajectory the uninterrupted run would have taken: P-SGD/QSGD
//! recover from the model alone, the residual/error-feedback schemes
//! (DORE/DIANA `h`, MEM-SGD/DoubleSqueeze `e`) restore their aux vectors
//! bit-for-bit.

use crate::F;
use anyhow::Context;
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 8] = b"DORECKPT";
/// v2 added the worker count (fleet-shape validation at resume); v1
/// files are rejected with an explicit version message, never
/// misinterpreted.
const VERSION: u32 = 2;

/// A snapshot of a training run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub algo: String,
    /// Rounds already completed; resuming starts at this round.
    pub round: u64,
    pub seed: u64,
    /// Fleet size the aux vectors were captured from.
    pub n_workers: u64,
    /// Master iterate x̂.
    pub model: Vec<F>,
    /// Named auxiliary state vectors (`m.h`, `m.e`, `w3.h`, ...).
    pub aux: Vec<(String, Vec<F>)>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn put_vec(out: &mut Vec<u8>, v: &[F]) {
    out.extend_from_slice(&(v.len() as u64).to_le_bytes());
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_vec(r: &mut impl Read) -> anyhow::Result<Vec<F>> {
    let mut len8 = [0u8; 8];
    r.read_exact(&mut len8)?;
    let len = u64::from_le_bytes(len8) as usize;
    anyhow::ensure!(len <= (1 << 31), "absurd vector length in checkpoint");
    let mut buf = vec![0u8; 4 * len];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| F::from_le_bytes(c.try_into().unwrap())).collect())
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(r: &mut impl Read) -> anyhow::Result<String> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    anyhow::ensure!(len <= 4096, "absurd string length in checkpoint");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

impl Checkpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::new();
        put_str(&mut body, &self.algo);
        body.extend_from_slice(&self.round.to_le_bytes());
        body.extend_from_slice(&self.seed.to_le_bytes());
        body.extend_from_slice(&self.n_workers.to_le_bytes());
        put_vec(&mut body, &self.model);
        body.extend_from_slice(&(self.aux.len() as u32).to_le_bytes());
        for (name, v) in &self.aux {
            put_str(&mut body, name);
            put_vec(&mut body, v);
        }
        let mut out = Vec::with_capacity(body.len() + 24);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&fnv1a(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            bytes.len() > 20,
            "checkpoint truncated: {} bytes is shorter than the fixed header",
            bytes.len()
        );
        anyhow::ensure!(&bytes[..8] == MAGIC, "bad checkpoint magic (not a DORE checkpoint file)");
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        anyhow::ensure!(
            version == VERSION,
            "unsupported checkpoint version {version} (this build reads version {VERSION})"
        );
        let checksum = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let body = &bytes[20..];
        anyhow::ensure!(
            fnv1a(body) == checksum,
            "checkpoint checksum mismatch (corrupt or truncated file)"
        );
        let mut r = body;
        let algo = get_str(&mut r)?;
        let mut u8buf = [0u8; 8];
        r.read_exact(&mut u8buf)?;
        let round = u64::from_le_bytes(u8buf);
        r.read_exact(&mut u8buf)?;
        let seed = u64::from_le_bytes(u8buf);
        r.read_exact(&mut u8buf)?;
        let n_workers = u64::from_le_bytes(u8buf);
        let model = get_vec(&mut r)?;
        let mut n4 = [0u8; 4];
        r.read_exact(&mut n4)?;
        let n_aux = u32::from_le_bytes(n4) as usize;
        anyhow::ensure!(n_aux <= 4096, "absurd aux count");
        let mut aux = Vec::with_capacity(n_aux);
        for _ in 0..n_aux {
            let name = get_str(&mut r)?;
            aux.push((name, get_vec(&mut r)?));
        }
        Ok(Self { algo, round, seed, n_workers, model, aux })
    }

    /// Atomic write: temp file + rename, so a crash never leaves a torn
    /// checkpoint at the destination path.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_bytes())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing checkpoint {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            algo: "DORE".into(),
            round: 1234,
            seed: 42,
            n_workers: 3,
            model: vec![1.0, -2.5, 3.25, 0.0],
            aux: vec![("m.h".into(), vec![0.5; 4]), ("m.e".into(), vec![-0.25; 4])],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let c = sample();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn detects_corruption() {
        let mut bytes = sample().to_bytes();
        let at = bytes.len() - 3;
        bytes[at] ^= 0xff;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        let mut bytes2 = sample().to_bytes();
        bytes2[8] = 99;
        let err = Checkpoint::from_bytes(&bytes2).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn rejects_truncated_file() {
        let bytes = sample().to_bytes();
        for cut in [5, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                Checkpoint::from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut} accepted"
            );
        }
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join(format!("dore-ckpt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
