//! Worker side of the socket coordinator: the registration handshake, the
//! per-socket link, and the session body shared by local worker threads
//! ([`super::tcp::TcpTransport`] spawns them) and the standalone
//! `dore-worker` binary ([`run_remote_worker`]). Both paths speak the
//! versioned [`crate::engine::protocol`] frames and execute the same
//! worker round schedule as every other transport — a remote process is
//! bit-identical to a local thread by construction.
//!
//! Registration is one exchange: the worker sends a
//! [`FrameKind::Hello`] (or [`FrameKind::Reconnect`] when re-registering
//! after a lost connection) carrying a [`HelloBody`] — model dimension,
//! fleet size, and the [`spec_fingerprint`] of its training spec — and the
//! master replies with a [`FrameKind::Sync`] naming the start round. An
//! empty Sync payload means "run from your own deterministic
//! initialization"; a non-empty one carries a [`SyncBody`] (model + aux
//! state) the worker imports first — the resume path for rejoiners and for
//! fresh processes joining a checkpoint-resumed master. A
//! [`FrameKind::Drain`] reply is a rejection: its payload is the master's
//! error text (version skew is caught even earlier, by the frame header
//! itself). After its last round a worker sends a Drain frame carrying its
//! final-model digest so an external master can verify fleet sync without
//! joining threads.
//!
//! Workers keep plain blocking sockets: the asymmetry is deliberate. Each
//! worker owns exactly one connection, so blocking reads cost it nothing,
//! while the master multiplexes the whole fleet onto the single
//! readiness-driven reactor in [`super::reactor`] — the worker never
//! needs to know. One protocol consequence matters for the master's
//! bookkeeping: a (re)registering worker blocks on the Sync reply before
//! sending any uplink, so the master may safely treat the hello as the
//! last small pre-registration frame on that connection.

use super::link::SocketLink;
use crate::algorithms::{digest_f32, WorkerNode};
use crate::engine::protocol::{
    drain_digest_payload, read_frame, spec_fingerprint, write_frame, Frame, FrameKind, HelloBody,
    SyncBody,
};
use crate::engine::registry;
use crate::engine::transport::WorkerSchedule;
use crate::engine::TrainSpec;
use crate::models::Problem;
use anyhow::Context;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

/// Everything a worker session needs to run (bundled so the spawn sites
/// stay readable).
pub(crate) struct WorkerBoot {
    pub(crate) id: usize,
    pub(crate) n: usize,
    pub(crate) addr: SocketAddr,
    pub(crate) problem: Arc<dyn Problem>,
    pub(crate) spec: TrainSpec,
    /// Chaos knob: vanish (dropping the socket) just before this round —
    /// the stand-in for `kill -9` on a worker process.
    pub(crate) crash_at: Option<usize>,
}

/// The registration exchange. Returns `None` when a *rejoiner* finds the
/// master gone (the run finished first — a clean exit, not an error);
/// otherwise the start round plus the state to import, if any.
fn register(
    sock: &mut TcpStream,
    boot: &WorkerBoot,
    rejoin: bool,
) -> anyhow::Result<Option<(usize, Option<SyncBody>)>> {
    let dim = boot.problem.dim();
    let hello = HelloBody {
        dim: dim as u32,
        n_workers: boot.n as u32,
        fingerprint: spec_fingerprint(&boot.spec, dim, boot.n),
    };
    let kind = if rejoin { FrameKind::Reconnect } else { FrameKind::Hello };
    let frame = Frame {
        kind,
        round: 0,
        worker: boot.id as u32,
        residual: 0.0,
        payload: hello.encode(),
    };
    if write_frame(sock, &frame).is_err() {
        anyhow::ensure!(rejoin, "master hung up during registration");
        return Ok(None);
    }
    // bound the wait with a plain socket timeout (no wall-clock reads)
    sock.set_read_timeout(Some(Duration::from_secs(30)))?;
    let reply = match read_frame(sock) {
        Ok(f) => f,
        Err(e) => {
            if rejoin {
                return Ok(None); // run finished before we were re-admitted
            }
            return Err(e.context("reading the master's registration reply"));
        }
    };
    sock.set_read_timeout(None)?;
    match reply.kind {
        FrameKind::Sync => {
            let body = if reply.payload.is_empty() {
                None
            } else {
                Some(SyncBody::decode(&reply.payload)?)
            };
            Ok(Some((reply.round as usize, body)))
        }
        FrameKind::Drain => anyhow::bail!(
            "master rejected worker {} registration: {}",
            boot.id,
            String::from_utf8_lossy(&reply.payload)
        ),
        other => anyhow::bail!("expected a sync frame after hello, got {other:?}"),
    }
}

/// The shared round body of fresh and rejoining workers — the one
/// [`WorkerSchedule`] every byte-moving transport runs, over a socket
/// link. Returns `None` if the chaos knob fired (simulated kill), else a
/// digest of the final model; on completion the digest also goes out as a
/// Drain frame (best-effort — a local master verifies via thread joins
/// and may already be tearing down).
fn run_rounds(
    sock: &mut TcpStream,
    node: &mut dyn WorkerNode,
    boot: &WorkerBoot,
    start: usize,
) -> anyhow::Result<Option<u64>> {
    let schedule = WorkerSchedule {
        n: boot.n,
        id: boot.id,
        start,
        crash_at: boot.crash_at,
        problem: boot.problem.as_ref(),
        spec: &boot.spec,
    };
    let mut link = SocketLink { sock, id: boot.id };
    if !schedule.run(node, &mut link)? {
        return Ok(None);
    }
    let digest = digest_f32(node.model());
    let _ = write_frame(
        sock,
        &Frame {
            kind: FrameKind::Drain,
            round: boot.spec.iters as u32,
            worker: boot.id as u32,
            residual: 0.0,
            payload: drain_digest_payload(digest),
        },
    );
    Ok(Some(digest))
}

/// One worker session over an established socket: register, import any
/// synced state, run the rounds.
fn worker_session(
    mut sock: TcpStream,
    boot: &WorkerBoot,
    node: &mut dyn WorkerNode,
    rejoin: bool,
) -> anyhow::Result<Option<u64>> {
    sock.set_nodelay(true)?;
    let Some((start, sync)) = register(&mut sock, boot, rejoin)? else {
        return Ok(None);
    };
    if let Some(body) = sync {
        // rejoiners get a model-only body (residual state zeroed — the
        // master's h/error state carries what the algebra needs); a fresh
        // process joining a resumed master gets its full exported state
        node.import_state(&body.model, &body.aux)?;
    }
    run_rounds(&mut sock, node, boot, start)
}

/// One local worker thread: connect, register (fresh hello or reconnect
/// handshake), run the rounds. A rejoining worker that cannot complete
/// its handshake (the master already shut down) exits cleanly with
/// `None` instead of failing the run.
pub(crate) fn tcp_worker_main(
    boot: WorkerBoot,
    mut node: Box<dyn WorkerNode>,
    rejoin: bool,
) -> anyhow::Result<Option<u64>> {
    let sock = if rejoin {
        match TcpStream::connect(boot.addr) {
            Ok(s) => s,
            Err(_) => return Ok(None), // master is gone; nothing to rejoin
        }
    } else {
        TcpStream::connect(boot.addr)?
    };
    worker_session(sock, &boot, node.as_mut(), rejoin)
}

/// The `dore-worker` binary's entry point: rebuild worker `slot`'s node
/// deterministically through the registry (the same construction the
/// master's session uses, so a fresh fleet and a single-process run are
/// bit-identical), connect to the master — retrying for ~10 s so the
/// processes can be launched in any order — and run the session. Returns
/// the final-model digest, or `None` if the crash knob fired or a rejoin
/// found the run already finished.
pub fn run_remote_worker(
    addr: &str,
    slot: usize,
    n: usize,
    rejoin: bool,
    crash_at: Option<usize>,
    problem: Arc<dyn Problem>,
    spec: TrainSpec,
) -> anyhow::Result<Option<u64>> {
    anyhow::ensure!(slot < n, "worker slot {slot} out of range for a fleet of {n}");
    let sockaddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving master address {addr}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("master address {addr} resolved to nothing"))?;
    let x0 = problem.init();
    let (mut fleet, _master) = match &spec.algo_name {
        Some(name) => registry::build_by_name(name, n, &x0, &spec.hp)?,
        None => registry::build_algorithm(spec.algo, n, &x0, &spec.hp)?,
    };
    let node = fleet.swap_remove(slot);
    // count-based retry: no wall-clock reads, just bounded attempts
    const ATTEMPTS: usize = 200;
    let mut sock = None;
    for attempt in 0..ATTEMPTS {
        match TcpStream::connect(sockaddr) {
            Ok(s) => {
                sock = Some(s);
                break;
            }
            Err(e) if attempt + 1 == ATTEMPTS => {
                return Err(anyhow::Error::from(e)
                    .context(format!("connecting to the master at {addr}")));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
    let sock = sock.expect("connected or bailed");
    let boot = WorkerBoot { id: slot, n, addr: sockaddr, problem, spec, crash_at };
    let mut node = node;
    worker_session(sock, &boot, node.as_mut(), rejoin)
}
