//! Link layer: the worker-side view of one master connection. The frames
//! themselves are the versioned [`crate::engine::protocol`] wire format;
//! this module owns *how* they cross one blocking socket, never *what*
//! they mean — sequencing and semantics stay in [`super::worker`].
//!
//! The master side no longer lives here: every master-side socket —
//! reassembly buffers, buffered nonblocking writes, readiness dispatch —
//! is owned by the single reactor in [`super::reactor`], driven from
//! [`super::tcp`]. There are no per-connection threads anywhere on the
//! master anymore (the old `Conn` + downlink-writer-thread pair this
//! module used to host is gone).
//!
//! Everything here is deadline-free by design: the blocking reads are
//! bounded only by whatever socket read timeout the caller set. That
//! keeps the link layer inside the determinism lint without a single
//! `lint:allow`.

use crate::engine::protocol::{read_frame, write_frame, Frame, FrameKind};
use crate::engine::transport::WorkerLink;
use std::net::TcpStream;

/// [`WorkerLink`] over one blocking socket: downlinks are read off the
/// same stream uplinks are written to. Frames move as raw payload bytes —
/// decoding and state application live in the engine's worker driver, so
/// a socketed worker and a channel worker share one semantics.
pub(crate) struct SocketLink<'a> {
    pub(crate) sock: &'a mut TcpStream,
    pub(crate) id: usize,
}

impl WorkerLink for SocketLink<'_> {
    fn recv_downlink(&mut self, round: usize) -> anyhow::Result<Vec<u8>> {
        let down = read_frame(self.sock)?;
        anyhow::ensure!(
            down.kind == FrameKind::Downlink,
            "expected a downlink frame, got {:?}",
            down.kind
        );
        anyhow::ensure!(
            down.round == round as u32,
            "round skew: expecting downlink {round}, got {}",
            down.round
        );
        Ok(down.payload)
    }

    fn send_uplink(
        &mut self,
        round: usize,
        bytes: Vec<u8>,
        residual_norm: f64,
    ) -> anyhow::Result<()> {
        write_frame(
            self.sock,
            &Frame {
                kind: FrameKind::Uplink,
                round: round as u32,
                worker: self.id as u32,
                residual: residual_norm,
                payload: bytes,
            },
        )
    }
}
