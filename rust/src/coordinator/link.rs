//! Link layer: per-connection machinery shared by every socket-moving
//! coordinator — nonblocking reads with a reassembly buffer, a dedicated
//! downlink writer thread per connection, and the buffered blocking read
//! used by drain/handshake paths. The frames themselves are the versioned
//! [`crate::engine::protocol`] wire format; this module owns *how* they
//! cross one socket, never *what* they mean — sequencing and semantics
//! stay in [`super::tcp`] (master) and [`super::worker`] (worker).
//!
//! Everything here is deadline-free by design: reads are either
//! nonblocking ([`conn_try_read`], the master's poll loop supplies its own
//! deadline) or bounded by a plain socket read timeout set by the caller.
//! That keeps the link layer inside the determinism lint without a single
//! `lint:allow`.

use crate::engine::protocol::{read_frame, take_frame, write_frame, DownlinkMsg, Frame, FrameKind};
use crate::engine::transport::WorkerLink;
use std::io::{ErrorKind, Read};
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, SyncSender};
use std::thread::JoinHandle;

/// [`WorkerLink`] over one blocking socket: downlinks are read off the
/// same stream uplinks are written to. Frames move as raw payload bytes —
/// decoding and state application live in the engine's worker driver, so
/// a socketed worker and a channel worker share one semantics.
pub(crate) struct SocketLink<'a> {
    pub(crate) sock: &'a mut TcpStream,
    pub(crate) id: usize,
}

impl WorkerLink for SocketLink<'_> {
    fn recv_downlink(&mut self, round: usize) -> anyhow::Result<Vec<u8>> {
        let down = read_frame(self.sock)?;
        anyhow::ensure!(
            down.kind == FrameKind::Downlink,
            "expected a downlink frame, got {:?}",
            down.kind
        );
        anyhow::ensure!(
            down.round == round as u32,
            "round skew: expecting downlink {round}, got {}",
            down.round
        );
        Ok(down.payload)
    }

    fn send_uplink(
        &mut self,
        round: usize,
        bytes: Vec<u8>,
        residual_norm: f64,
    ) -> anyhow::Result<()> {
        write_frame(
            self.sock,
            &Frame {
                kind: FrameKind::Uplink,
                round: round as u32,
                worker: self.id as u32,
                residual: residual_norm,
                payload: bytes,
            },
        )
    }
}

/// One live master-side connection: the nonblocking read half with its
/// reassembly buffer, plus the writer thread feeding the write half.
pub(crate) struct Conn {
    pub(crate) sock: TcpStream,
    pub(crate) buf: Vec<u8>,
    pub(crate) writer_tx: Option<SyncSender<DownlinkMsg>>,
    pub(crate) writer: Option<JoinHandle<anyhow::Result<()>>>,
}

/// Wire up a connection: clone the socket for the writer thread and bound
/// its feeding channel at the pipeline depth (a worker that keeps
/// consuming downlinks never backs the master up, while a wedged fleet
/// exerts backpressure instead of queueing the whole run's broadcasts).
pub(crate) fn spawn_conn(sock: TcpStream, id: usize, depth: usize) -> anyhow::Result<Conn> {
    let w = sock.try_clone()?;
    let (tx, rx) = std::sync::mpsc::sync_channel::<DownlinkMsg>(depth);
    let writer = std::thread::Builder::new()
        .name(format!("dore-link-down-{id}"))
        .spawn(move || downlink_writer(w, rx))?;
    Ok(Conn { sock, buf: Vec::new(), writer_tx: Some(tx), writer: Some(writer) })
}

/// Flush-and-join a connection's writer (its broken-pipe exit is an
/// expected fault path) and drop the socket.
pub(crate) fn close_conn(mut conn: Conn) {
    conn.writer_tx = None;
    if let Some(h) = conn.writer.take() {
        let _ = h.join();
    }
}

/// The per-connection downlink writer: drains queued broadcasts onto its
/// write half of the socket so the master's read loop never blocks on a
/// full send buffer (the depth ≥ 2 deadlock guard — see the
/// [`super::tcp`] module docs). Exits when the master drops its sender
/// (remaining queued frames are flushed first) or when the peer vanishes
/// mid-write — a rejoining replacement gets a fresh writer plus a model
/// sync, so a broken pipe here is an expected fault, not an error.
fn downlink_writer(mut sock: TcpStream, rx: Receiver<DownlinkMsg>) -> anyhow::Result<()> {
    while let Ok(m) = rx.recv() {
        let frame = Frame {
            kind: FrameKind::Downlink,
            round: m.round as u32,
            worker: 0,
            residual: 0.0,
            payload: m.bytes,
        };
        if write_frame(&mut sock, &frame).is_err() {
            return Ok(());
        }
    }
    Ok(())
}

/// One nonblocking read attempt's outcome.
pub(crate) enum SockRead {
    Frame(Frame),
    WouldBlock,
    Lost,
}

/// Pull at most one complete frame off a nonblocking connection,
/// buffering partial bytes in the reassembly buffer across calls. EOF,
/// reset and broken-pipe are all `Lost` (the connection-fault path);
/// anything else is a real error.
pub(crate) fn conn_try_read(conn: &mut Conn) -> anyhow::Result<SockRead> {
    loop {
        if let Some(f) = take_frame(&mut conn.buf)? {
            return Ok(SockRead::Frame(f));
        }
        let mut chunk = [0u8; 16384];
        match conn.sock.read(&mut chunk) {
            Ok(0) => return Ok(SockRead::Lost),
            Ok(k) => conn.buf.extend_from_slice(&chunk[..k]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(SockRead::WouldBlock),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                        | ErrorKind::BrokenPipe
                ) =>
            {
                return Ok(SockRead::Lost)
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Blocking read of the next frame through an existing reassembly buffer:
/// frames already (partially) buffered by earlier nonblocking reads are
/// drained first, then the socket is read blockingly. Used by drain and
/// handshake paths on sockets switched back to blocking mode; bound the
/// wait with `sock.set_read_timeout` at the call site.
pub(crate) fn read_frame_buffered(conn: &mut Conn) -> anyhow::Result<Frame> {
    loop {
        if let Some(f) = take_frame(&mut conn.buf)? {
            return Ok(f);
        }
        let mut chunk = [0u8; 16384];
        match conn.sock.read(&mut chunk) {
            Ok(0) => anyhow::bail!("connection closed mid-frame"),
            Ok(k) => conn.buf.extend_from_slice(&chunk[..k]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
}
