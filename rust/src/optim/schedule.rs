//! Learning-rate schedules. §5.2 uses step decay: initial lr 0.1 (LeNet) /
//! 0.01 (ResNet18) decayed ×0.1 every 25 / 100 epochs.

use crate::F;

#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant(F),
    /// `base · factor^{⌊round / every⌋}`
    StepDecay { base: F, factor: F, every: usize },
    /// Linear warmup to `base` over `warmup` rounds, constant after.
    Warmup { base: F, warmup: usize },
}

impl LrSchedule {
    pub fn at(&self, round: usize) -> F {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::StepDecay { base, factor, every } => {
                base * factor.powi((round / every.max(1)) as i32)
            }
            LrSchedule::Warmup { base, warmup } => {
                if round < warmup {
                    base * (round + 1) as F / warmup as F
                } else {
                    base
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant() {
        assert_eq!(LrSchedule::Constant(0.1).at(1000), 0.1);
    }

    #[test]
    fn step_decay_matches_paper_settings() {
        // lr 0.1, ×0.1 every 25 epochs
        let s = LrSchedule::StepDecay { base: 0.1, factor: 0.1, every: 25 };
        assert!((s.at(0) - 0.1).abs() < 1e-9);
        assert!((s.at(24) - 0.1).abs() < 1e-9);
        assert!((s.at(25) - 0.01).abs() < 1e-9);
        assert!((s.at(50) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn warmup_ramps() {
        let s = LrSchedule::Warmup { base: 1.0, warmup: 4 };
        assert!((s.at(0) - 0.25).abs() < 1e-7);
        assert!((s.at(3) - 1.0).abs() < 1e-7);
        assert!((s.at(10) - 1.0).abs() < 1e-7);
    }
}
