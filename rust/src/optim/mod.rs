//! Optimizer substrate: proximal operators for the regularizer `R` in
//! Algorithm 1, and learning-rate schedules used by the nonconvex
//! experiments (step decay ×0.1 every 25/100 epochs, §5.2).

pub mod prox;
pub mod schedule;

pub use prox::Prox;
pub use schedule::LrSchedule;
