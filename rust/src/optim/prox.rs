//! Proximal operators `prox_{γR}(v) = argmin_x R(x) + ‖x − v‖²/(2γ)` for
//! the closed convex regularizers Algorithm 1 supports.

use crate::F;

/// The regularizer `R` of the composite objective `f + R`.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum Prox {
    /// `R = 0` — prox is the identity (Algorithm 2, the smooth case).
    #[default]
    None,
    /// `R(x) = λ‖x‖₁` — soft thresholding.
    L1 { lambda: F },
    /// `R(x) = (λ/2)‖x‖²` — shrinkage by `1/(1+γλ)`.
    L2 { lambda: F },
    /// Indicator of the centered box `{x : ‖x‖_∞ ≤ r}` — projection.
    BoxConstraint { radius: F },
}

impl Prox {
    /// Apply `prox_{γR}` in place.
    pub fn apply(&self, gamma: F, x: &mut [F]) {
        match *self {
            Prox::None => {}
            Prox::L1 { lambda } => {
                let t = gamma * lambda;
                for v in x.iter_mut() {
                    *v = v.signum() * (v.abs() - t).max(0.0);
                }
            }
            Prox::L2 { lambda } => {
                let s = 1.0 / (1.0 + gamma * lambda);
                for v in x.iter_mut() {
                    *v *= s;
                }
            }
            Prox::BoxConstraint { radius } => {
                for v in x.iter_mut() {
                    *v = v.clamp(-radius, radius);
                }
            }
        }
    }

    /// Scalar prox — all supported regularizers are separable, so the hot
    /// path can fuse `prox_{γR}` into surrounding elementwise sweeps
    /// (§Perf). Must agree with [`Prox::apply`] coordinate-wise.
    #[inline(always)]
    pub fn apply_one(&self, gamma: F, v: F) -> F {
        match *self {
            Prox::None => v,
            Prox::L1 { lambda } => {
                let t = gamma * lambda;
                v.signum() * (v.abs() - t).max(0.0)
            }
            Prox::L2 { lambda } => v / (1.0 + gamma * lambda),
            Prox::BoxConstraint { radius } => v.clamp(-radius, radius),
        }
    }

    /// The regularizer value `R(x)` (for composite-objective reporting).
    pub fn value(&self, x: &[F]) -> f64 {
        match *self {
            Prox::None => 0.0,
            Prox::L1 { lambda } => lambda as f64 * x.iter().map(|v| v.abs() as f64).sum::<f64>(),
            Prox::L2 { lambda } => {
                0.5 * lambda as f64 * x.iter().map(|v| (v * v) as f64).sum::<f64>()
            }
            Prox::BoxConstraint { radius } => {
                if x.iter().all(|v| v.abs() <= radius + 1e-7) {
                    0.0
                } else {
                    f64::INFINITY
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_soft_threshold() {
        let p = Prox::L1 { lambda: 1.0 };
        let mut x = vec![3.0, -0.25, 0.5, -2.0];
        p.apply(0.5, &mut x);
        assert_eq!(x, vec![2.5, 0.0, 0.0, -1.5]);
    }

    #[test]
    fn l2_shrinkage() {
        let p = Prox::L2 { lambda: 2.0 };
        let mut x = vec![3.0, -1.0];
        p.apply(0.5, &mut x); // scale 1/(1+1) = 0.5
        assert_eq!(x, vec![1.5, -0.5]);
    }

    #[test]
    fn box_projection() {
        let p = Prox::BoxConstraint { radius: 1.0 };
        let mut x = vec![3.0, -2.0, 0.5];
        p.apply(0.1, &mut x);
        assert_eq!(x, vec![1.0, -1.0, 0.5]);
        assert_eq!(p.value(&x), 0.0);
    }

    #[test]
    fn prox_defining_inequality_l1() {
        // prox_{γR}(v) minimizes R(x) + ||x-v||²/(2γ): check vs perturbations.
        let p = Prox::L1 { lambda: 0.7 };
        let v = vec![1.3, -0.2, 0.9];
        let gamma = 0.4;
        let mut x = v.clone();
        p.apply(gamma, &mut x);
        let obj = |y: &[F]| {
            p.value(y)
                + y.iter()
                    .zip(&v)
                    .map(|(a, b)| ((a - b) * (a - b)) as f64)
                    .sum::<f64>()
                    / (2.0 * gamma as f64)
        };
        let base = obj(&x);
        for j in 0..3 {
            for d in [-0.05f32, 0.05] {
                let mut y = x.clone();
                y[j] += d;
                assert!(obj(&y) >= base - 1e-9);
            }
        }
    }
}
