//! Deterministic network timing model (Fig. 2 substrate).
//!
//! Model: the parameter server and `n` workers share a star topology. In a
//! synchronous round,
//!
//! 1. **gather** — all workers transmit their uplinks concurrently; the
//!    master's ingress NIC is the bottleneck, so gather time is
//!    `Σ_i bits_i / bandwidth + latency` (serialized at the master, the
//!    standard PS incast model, matching the paper's observation that the
//!    master link dominates);
//! 2. **broadcast** — the master sends the downlink once per worker over
//!    its egress: `n · bits_down / bandwidth + latency`.
//!
//! The round time is `compute + gather + broadcast`. Everything is
//! deterministic; the harness sweeps `bandwidth` to regenerate Fig. 2.

/// Link characteristics.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Bits per second, e.g. `1e9` for Gigabit Ethernet.
    pub bandwidth_bps: f64,
    /// One-way latency per message, seconds.
    pub latency_s: f64,
}

impl LinkSpec {
    pub fn gigabit() -> Self {
        Self { bandwidth_bps: 1e9, latency_s: 100e-6 }
    }

    pub fn with_bandwidth(bps: f64) -> Self {
        Self { bandwidth_bps: bps, latency_s: 100e-6 }
    }

    /// Time to move `bits` over this link once.
    pub fn transfer_time(&self, bits: u64) -> f64 {
        self.latency_s + bits as f64 / self.bandwidth_bps
    }
}

/// Star-topology round-time model.
#[derive(Clone, Debug)]
pub struct NetSim {
    pub link: LinkSpec,
    pub n_workers: usize,
    /// Simulated seconds elapsed.
    pub clock_s: f64,
}

impl NetSim {
    pub fn new(link: LinkSpec, n_workers: usize) -> Self {
        Self { link, n_workers, clock_s: 0.0 }
    }

    /// Advance the clock by one synchronous round and return its duration.
    ///
    /// `uplink_bits` is per-worker (all equal-size in the algorithms here),
    /// `downlink_bits` is the broadcast payload size, `compute_s` the
    /// max per-node gradient+compression compute time.
    pub fn round(&mut self, uplink_bits: u64, downlink_bits: u64, compute_s: f64) -> f64 {
        let gather = self.link.latency_s
            + (self.n_workers as u64 * uplink_bits) as f64 / self.link.bandwidth_bps;
        let bcast = self.link.latency_s
            + (self.n_workers as u64 * downlink_bits) as f64 / self.link.bandwidth_bps;
        let dt = compute_s + gather + bcast;
        self.clock_s += dt;
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bits() {
        let l = LinkSpec::gigabit();
        let t1 = l.transfer_time(1_000_000);
        let t2 = l.transfer_time(2_000_000);
        assert!((t2 - t1 - 0.001).abs() < 1e-9); // +1 Mbit at 1 Gbps = 1 ms
    }

    #[test]
    fn round_time_composition() {
        let mut net = NetSim::new(LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 }, 2);
        // 2 workers × 1e6 bits up = 2 s; 2 × 0.5e6 down = 1 s; compute 0.5 s
        let dt = net.round(1_000_000, 500_000, 0.5);
        assert!((dt - 3.5).abs() < 1e-9, "dt={dt}");
        assert!((net.clock_s - 3.5).abs() < 1e-9);
    }

    #[test]
    fn lower_bandwidth_hurts_uncompressed_more() {
        // The Fig. 2 qualitative shape: at low bandwidth, a 32d scheme's
        // round is ~20× slower than a 1.6-bit scheme's.
        let d = 1_000_000u64;
        let dense = 32 * d;
        let tern = 32 * d / 256 + 8 * d.div_ceil(5);
        for bw in [1e9, 1e8, 1e7] {
            let mut a = NetSim::new(LinkSpec::with_bandwidth(bw), 10);
            let mut b = NetSim::new(LinkSpec::with_bandwidth(bw), 10);
            let ta = a.round(dense, dense, 0.0);
            let tb = b.round(tern, tern, 0.0);
            let ratio = ta / tb;
            assert!(ratio > 15.0, "bw={bw} ratio={ratio}");
        }
    }
}
