//! Deterministic network timing model (Fig. 2 substrate).
//!
//! Model: the parameter server and `n` workers share a star topology. In a
//! synchronous round,
//!
//! 1. **gather** — all workers transmit their uplinks concurrently; the
//!    master's ingress NIC is the bottleneck, so gather time is
//!    `Σ_i bits_i / bandwidth + latency` (serialized at the master, the
//!    standard PS incast model, matching the paper's observation that the
//!    master link dominates);
//! 2. **broadcast** — the master sends the downlink once per worker over
//!    its egress: `n · bits_down / bandwidth + latency`.
//!
//! The round time is `compute + gather + broadcast`. Everything is
//! deterministic; the harness sweeps `bandwidth` to regenerate Fig. 2.
//!
//! [`StragglerSpec`] adds per-worker heterogeneity on top of the link
//! model: a compute multiplier for a deterministic slice of the fleet and
//! seeded per-round latency jitter. Combined with k-of-n partial
//! participation (see [`crate::engine::Participation`]) the gather term
//! waits only for the slowest *awaited* uplink — the k-th arrival, not the
//! n-th — which is the whole point of straggler-aware rounds.

use crate::compression::Xoshiro256;

/// Link characteristics.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Bits per second, e.g. `1e9` for Gigabit Ethernet.
    pub bandwidth_bps: f64,
    /// One-way latency per message, seconds.
    pub latency_s: f64,
}

impl LinkSpec {
    pub fn gigabit() -> Self {
        Self { bandwidth_bps: 1e9, latency_s: 100e-6 }
    }

    pub fn with_bandwidth(bps: f64) -> Self {
        Self { bandwidth_bps: bps, latency_s: 100e-6 }
    }

    /// Time to move `bits` over this link once.
    pub fn transfer_time(&self, bits: u64) -> f64 {
        self.latency_s + bits as f64 / self.bandwidth_bps
    }
}

/// Per-worker compute/latency heterogeneity for the simulated network.
///
/// Workers `0..⌈slow_fraction·n⌉` are the permanently slow slice of the
/// fleet (assignment is deterministic so runs replay bit-for-bit); every
/// worker additionally draws uniform per-round latency jitter in
/// `[0, jitter_s)` from the run seed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerSpec {
    /// Compute-time multiplier applied to the slow slice (≥ 1).
    pub slow_factor: f64,
    /// Fraction of the fleet that is permanently slow.
    pub slow_fraction: f64,
    /// Upper bound of the per-worker per-round uniform latency jitter, in
    /// seconds.
    pub jitter_s: f64,
}

/// Salt separating the jitter RNG stream from the training sites.
const JITTER_SALT: u64 = 0x6a69_7474_6572; // "jitter"

impl Default for StragglerSpec {
    fn default() -> Self {
        Self::none()
    }
}

impl StragglerSpec {
    /// A homogeneous fleet: multiplier 1, no jitter.
    pub fn none() -> Self {
        Self { slow_factor: 1.0, slow_fraction: 0.0, jitter_s: 0.0 }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.slow_factor >= 1.0 && self.slow_factor.is_finite(),
            "straggler slow_factor must be ≥ 1, got {}",
            self.slow_factor
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.slow_fraction),
            "straggler slow_fraction must be in [0, 1], got {}",
            self.slow_fraction
        );
        anyhow::ensure!(
            self.jitter_s >= 0.0 && self.jitter_s.is_finite(),
            "straggler jitter_s must be ≥ 0, got {}",
            self.jitter_s
        );
        Ok(())
    }

    /// How many of `n` workers are in the slow slice.
    pub fn slow_count(&self, n: usize) -> usize {
        ((self.slow_fraction * n as f64).ceil() as usize).min(n)
    }

    /// Compute-time multiplier for `worker` in a fleet of `n`.
    pub fn compute_factor(&self, worker: usize, n: usize) -> f64 {
        if worker < self.slow_count(n) {
            self.slow_factor
        } else {
            1.0
        }
    }

    /// Deterministic per-round latency jitter for `worker`, seconds.
    pub fn jitter(&self, seed: u64, worker: usize, round: usize) -> f64 {
        if self.jitter_s <= 0.0 {
            return 0.0;
        }
        let mut rng = Xoshiro256::for_site(seed ^ JITTER_SALT, 1 + worker as u64, round as u64);
        rng.next_f64() * self.jitter_s
    }

    /// Readiness time of one worker's uplink: measured compute scaled by
    /// the straggler multiplier plus that round's jitter draw.
    pub fn ready_time(
        &self,
        seed: u64,
        worker: usize,
        n: usize,
        round: usize,
        compute_s: f64,
    ) -> f64 {
        compute_s * self.compute_factor(worker, n) + self.jitter(seed, worker, round)
    }
}

/// `mult[:fraction[:jitter_s]]`, e.g. `--straggler 4:0.25:0.002` — the slow
/// quarter of the fleet computes 4× slower and every uplink jitters by up
/// to 2 ms.
impl std::str::FromStr for StragglerSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let mut parts = s.split(':');
        let mut spec = StragglerSpec::none();
        if let Some(m) = parts.next().filter(|p| !p.is_empty()) {
            spec.slow_factor =
                m.parse().map_err(|e| anyhow::anyhow!("straggler factor '{m}': {e}"))?;
            // a bare multiplier with no fraction defaults to "half the fleet
            // is slow" so `--straggler 4` does something visible
            spec.slow_fraction = 0.5;
        }
        if let Some(f) = parts.next() {
            spec.slow_fraction =
                f.parse().map_err(|e| anyhow::anyhow!("straggler fraction '{f}': {e}"))?;
        }
        if let Some(j) = parts.next() {
            spec.jitter_s =
                j.parse().map_err(|e| anyhow::anyhow!("straggler jitter '{j}': {e}"))?;
        }
        anyhow::ensure!(parts.next().is_none(), "straggler spec '{s}' has too many fields");
        spec.validate()?;
        Ok(spec)
    }
}

/// Star-topology round-time model.
#[derive(Clone, Debug)]
pub struct NetSim {
    pub link: LinkSpec,
    pub n_workers: usize,
    /// Simulated seconds elapsed.
    pub clock_s: f64,
    /// Broadcast-completion times of the most recent rounds (at most the
    /// pipeline depth of them) — the [`NetSim::pipelined_round`] state that
    /// anchors when a round's uplink leg may start.
    down_done: std::collections::VecDeque<f64>,
}

impl NetSim {
    pub fn new(link: LinkSpec, n_workers: usize) -> Self {
        Self { link, n_workers, clock_s: 0.0, down_done: std::collections::VecDeque::new() }
    }

    /// Advance the clock by one synchronous round and return its duration.
    ///
    /// `uplink_bits` is per-worker (all equal-size in the algorithms here),
    /// `downlink_bits` is the broadcast payload size, `compute_s` the
    /// max per-node gradient+compression compute time.
    pub fn round(&mut self, uplink_bits: u64, downlink_bits: u64, compute_s: f64) -> f64 {
        let gather = self.link.latency_s
            + (self.n_workers as u64 * uplink_bits) as f64 / self.link.bandwidth_bps;
        let bcast = self.link.latency_s
            + (self.n_workers as u64 * downlink_bits) as f64 / self.link.bandwidth_bps;
        let dt = compute_s + gather + bcast;
        self.clock_s += dt;
        dt
    }

    /// Advance the clock by one *partial-participation* round.
    ///
    /// `slowest_ready_s` is the readiness time of the slowest uplink the
    /// barrier actually waited for (the k-th arrival under k-of-n, not the
    /// fleet-wide straggler), `gathered_uplink_bits` the total fresh bits
    /// that crossed the master's ingress this round (reused stale frames
    /// move nothing), `downlink_bits` the broadcast payload (still sent to
    /// all `n` workers).
    pub fn gather_round(
        &mut self,
        slowest_ready_s: f64,
        gathered_uplink_bits: u64,
        downlink_bits: u64,
    ) -> f64 {
        let gather =
            self.link.latency_s + gathered_uplink_bits as f64 / self.link.bandwidth_bps;
        let bcast = self.link.latency_s
            + (self.n_workers as u64 * downlink_bits) as f64 / self.link.bandwidth_bps;
        let dt = slowest_ready_s + gather + bcast;
        self.clock_s += dt;
        dt
    }

    /// Advance the clock by one round of a **pipelined** schedule with
    /// `depth` rounds in flight per link; returns the round's marginal
    /// clock advance. Call once per round, in round order.
    ///
    /// Model: round `t`'s workers start computing once they applied
    /// downlink `t − depth` (time `down_done[t − depth]`, 0 for the first
    /// `depth` rounds), so its uplink has fully arrived at
    /// `start + slowest_ready_s + (L + up_bits/bw)`. The master's egress
    /// serializes broadcasts across rounds (it is busy until the previous
    /// round's broadcast finished at the current `clock_s`), while its
    /// ingress is full-duplex — uplinks of round `t` stream in *behind*
    /// the broadcasts of rounds `t − depth + 1 .. t − 1`. The round's
    /// broadcast therefore runs over
    /// `[max(uplink_done, clock_s), … + (L + n·down_bits/bw)]`, and the
    /// clock advances to its end: on a latency-bound link the whole uplink
    /// leg hides behind the in-flight window and each steady-state round
    /// costs one broadcast leg instead of `ready + gather + bcast`.
    ///
    /// Charge the clock for `rejoined` workers re-registering after an
    /// outage: a connection handshake plus the hello/sync exchange
    /// (three one-way latencies) and a full model replay per rejoiner
    /// over the master's egress (serialized, like the broadcast path).
    /// The round engine calls this once per round with the number of
    /// fault-plan rejoin transitions; `rejoined = 0` is free.
    pub fn reconnect(&mut self, rejoined: usize, model_bits: u64) -> f64 {
        if rejoined == 0 {
            return 0.0;
        }
        let dt = 3.0 * self.link.latency_s
            + (rejoined as u64 * model_bits) as f64 / self.link.bandwidth_bps;
        self.clock_s += dt;
        dt
    }

    /// `depth = 1` reduces exactly to [`NetSim::gather_round`] (kept as
    /// the separate synchronous entry point so depth-1 clock arithmetic is
    /// bit-identical to the pre-pipeline model).
    pub fn pipelined_round(
        &mut self,
        depth: usize,
        slowest_ready_s: f64,
        gathered_uplink_bits: u64,
        downlink_bits: u64,
    ) -> f64 {
        if depth <= 1 {
            return self.gather_round(slowest_ready_s, gathered_uplink_bits, downlink_bits);
        }
        // completing round t: down_done holds rounds t-L..t-1 (L ≤ depth),
        // so its front is round t - depth exactly when the window is full
        let start = if self.down_done.len() >= depth {
            *self.down_done.front().expect("non-empty at depth")
        } else {
            0.0
        };
        let gather =
            self.link.latency_s + gathered_uplink_bits as f64 / self.link.bandwidth_bps;
        let uplink_done = start + slowest_ready_s + gather;
        let bcast = self.link.latency_s
            + (self.n_workers as u64 * downlink_bits) as f64 / self.link.bandwidth_bps;
        let end = uplink_done.max(self.clock_s) + bcast;
        let dt = end - self.clock_s;
        self.clock_s = end;
        self.down_done.push_back(end);
        if self.down_done.len() > depth {
            self.down_done.pop_front();
        }
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bits() {
        let l = LinkSpec::gigabit();
        let t1 = l.transfer_time(1_000_000);
        let t2 = l.transfer_time(2_000_000);
        assert!((t2 - t1 - 0.001).abs() < 1e-9); // +1 Mbit at 1 Gbps = 1 ms
    }

    #[test]
    fn round_time_composition() {
        let mut net = NetSim::new(LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 }, 2);
        // 2 workers × 1e6 bits up = 2 s; 2 × 0.5e6 down = 1 s; compute 0.5 s
        let dt = net.round(1_000_000, 500_000, 0.5);
        assert!((dt - 3.5).abs() < 1e-9, "dt={dt}");
        assert!((net.clock_s - 3.5).abs() < 1e-9);
    }

    #[test]
    fn gather_round_charges_only_gathered_bits() {
        let mut net = NetSim::new(LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 }, 4);
        // barrier waited 0.25 s for its slowest awaited worker; 2 of 4
        // workers uploaded 1e6 bits each; broadcast 0.5e6 to all 4.
        let dt = net.gather_round(0.25, 2_000_000, 500_000);
        assert!((dt - (0.25 + 2.0 + 2.0)).abs() < 1e-9, "dt={dt}");
    }

    #[test]
    fn reconnect_charges_handshake_plus_model_replay() {
        let mut net = NetSim::new(LinkSpec { bandwidth_bps: 1e6, latency_s: 0.01 }, 4);
        assert_eq!(net.reconnect(0, 1_000_000), 0.0);
        assert_eq!(net.clock_s, 0.0, "no rejoiners, no charge");
        // 2 rejoiners × 1e6 bits at 1e6 bps = 2 s replay + 3 × 10 ms
        let dt = net.reconnect(2, 1_000_000);
        assert!((dt - 2.03).abs() < 1e-9, "dt={dt}");
        assert!((net.clock_s - 2.03).abs() < 1e-9);
    }

    #[test]
    fn straggler_slice_and_jitter_are_deterministic() {
        let s = StragglerSpec { slow_factor: 4.0, slow_fraction: 0.25, jitter_s: 0.01 };
        assert_eq!(s.slow_count(8), 2);
        assert_eq!(s.compute_factor(1, 8), 4.0);
        assert_eq!(s.compute_factor(2, 8), 1.0);
        let a = s.jitter(42, 3, 17);
        let b = s.jitter(42, 3, 17);
        assert_eq!(a, b, "jitter must replay bit-for-bit");
        assert!((0.0..0.01).contains(&a));
        assert_ne!(s.jitter(42, 3, 18), a, "jitter varies per round");
        assert_eq!(StragglerSpec::none().jitter(42, 3, 17), 0.0);
    }

    #[test]
    fn straggler_spec_parses() {
        let s: StragglerSpec = "4".parse().unwrap();
        assert_eq!(s, StragglerSpec { slow_factor: 4.0, slow_fraction: 0.5, jitter_s: 0.0 });
        let s: StragglerSpec = "4:0.25".parse().unwrap();
        assert_eq!(s.slow_fraction, 0.25);
        let s: StragglerSpec = "4:0.25:0.002".parse().unwrap();
        assert_eq!(s.jitter_s, 0.002);
        assert!("0.5".parse::<StragglerSpec>().is_err(), "factor < 1 rejected");
        assert!("4:2".parse::<StragglerSpec>().is_err(), "fraction > 1 rejected");
        assert!("4:0.5:1:1".parse::<StragglerSpec>().is_err());
    }

    #[test]
    fn pipelined_depth_one_is_exactly_the_synchronous_model() {
        let link = LinkSpec { bandwidth_bps: 1e6, latency_s: 0.01 };
        let mut sync = NetSim::new(link, 4);
        let mut pipe = NetSim::new(link, 4);
        for _ in 0..5 {
            sync.gather_round(0.25, 2_000_000, 500_000);
            pipe.pipelined_round(1, 0.25, 2_000_000, 500_000);
        }
        assert_eq!(sync.clock_s.to_bits(), pipe.clock_s.to_bits());
    }

    #[test]
    fn pipelined_rounds_hide_the_uplink_leg_behind_the_broadcast() {
        // latency-dominated link: transfer terms are negligible, so a
        // synchronous round costs two latencies while a steady-state
        // depth-2 round costs one (the uplink leg of round t+1 rides
        // behind the broadcast of round t).
        let link = LinkSpec { bandwidth_bps: 1e9, latency_s: 0.1 };
        let mut sync = NetSim::new(link, 2);
        let mut pipe = NetSim::new(link, 2);
        let mut steady_dt = 0.0;
        for _ in 0..10 {
            sync.gather_round(0.0, 100, 100);
            steady_dt = pipe.pipelined_round(2, 0.0, 100, 100);
        }
        assert!(
            pipe.clock_s < 0.6 * sync.clock_s,
            "depth 2 {} vs depth 1 {}",
            pipe.clock_s,
            sync.clock_s
        );
        // steady state: one broadcast leg (latency + n·bits/bw) per round
        let bcast = link.latency_s + 2.0 * 100.0 / link.bandwidth_bps;
        assert!((steady_dt - bcast).abs() < 1e-9, "steady dt {steady_dt} vs bcast {bcast}");
    }

    #[test]
    fn pipelined_round_never_outruns_the_compute_chain() {
        // compute-bound fleet: ready dominates both legs, so pipelining
        // cannot beat ~ready/round by more than the hidden wire time —
        // round t still waits for downlink t−2 before computing.
        let link = LinkSpec { bandwidth_bps: 1e9, latency_s: 1e-4 };
        let mut pipe = NetSim::new(link, 2);
        for _ in 0..10 {
            pipe.pipelined_round(2, 1.0, 100, 100);
        }
        // 10 rounds of 1 s compute on a 2-deep pipeline: every other round
        // chains on the previous-but-one, so the clock is ≥ 5 s and ≤ ~6 s
        assert!(pipe.clock_s >= 5.0, "{}", pipe.clock_s);
        assert!(pipe.clock_s <= 6.5, "{}", pipe.clock_s);
    }

    #[test]
    fn lower_bandwidth_hurts_uncompressed_more() {
        // The Fig. 2 qualitative shape: at low bandwidth, a 32d scheme's
        // round is ~20× slower than a 1.6-bit scheme's.
        let d = 1_000_000u64;
        let dense = 32 * d;
        let tern = 32 * d / 256 + 8 * d.div_ceil(5);
        for bw in [1e9, 1e8, 1e7] {
            let mut a = NetSim::new(LinkSpec::with_bandwidth(bw), 10);
            let mut b = NetSim::new(LinkSpec::with_bandwidth(bw), 10);
            let ta = a.round(dense, dense, 0.0);
            let tb = b.round(tern, tern, 0.0);
            let ratio = ta / tb;
            assert!(ratio > 15.0, "bw={bw} ratio={ratio}");
        }
    }
}
