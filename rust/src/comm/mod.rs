//! Communication substrate: exact byte accounting and a network timing
//! model for the Fig. 2 bandwidth study.
//!
//! The paper's testbed is a parameter server + 10 workers on (shared)
//! Gigabit Ethernet. We replace the physical network with [`NetSim`], a
//! deterministic timing model over **exactly counted** wire bytes — the
//! payloads the coordinator moves are real encoded buffers from
//! [`crate::compression::codec`], so the byte counts are ground truth, and
//! only the *time* is modelled.

pub mod netsim;

pub use netsim::{LinkSpec, NetSim, StragglerSpec};

/// Per-direction traffic counters (bits).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrafficStats {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    pub uplink_msgs: u64,
    pub downlink_msgs: u64,
}

impl TrafficStats {
    pub fn record_uplink(&mut self, bits: u64) {
        self.uplink_bits += bits;
        self.uplink_msgs += 1;
    }

    pub fn record_downlink(&mut self, bits: u64) {
        self.downlink_bits += bits;
        self.downlink_msgs += 1;
    }

    pub fn total_bits(&self) -> u64 {
        self.uplink_bits + self.downlink_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut t = TrafficStats::default();
        t.record_uplink(100);
        t.record_uplink(50);
        t.record_downlink(30);
        assert_eq!(t.uplink_bits, 150);
        assert_eq!(t.uplink_msgs, 2);
        assert_eq!(t.total_bits(), 180);
    }
}
