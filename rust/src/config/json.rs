//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for config
//! files and the artifact manifest). Built in-crate because the environment
//! is offline (no serde_json); ~300 lines, fully tested.
//!
//! Supported: objects, arrays, strings (with \u escapes), numbers, bools,
//! null. Not supported: surrogate-pair round-tripping beyond the BMP is
//! mapped through `char::from_u32` with replacement.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| n.fract() == 0.0 && *n >= 0.0).map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| n.fract() == 0.0 && *n >= 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `get` + typed conversion with a decent error message.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(c),
            "expected '{}' at byte {}, found {:?}",
            c as char,
            self.i,
            self.peek().map(|b| b as char)
        );
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => anyhow::bail!("expected , or }} at byte {}, found {other:?}", self.i),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                other => anyhow::bail!("expected , or ] at byte {}, found {other:?}", self.i),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => anyhow::bail!("bad escape '\\{}'", other as char),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] & 0xc0 == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }
}

// -- serialization -----------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(), "c");
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(Json::parse("\"αβ\"").unwrap(), Json::Str("αβ".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"yes":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 5, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert_eq!(v.opt_usize("missing", 9), 9);
        assert!(v.req_usize("f").is_err()); // non-integer
        assert_eq!(v.opt_str("s", "d"), "x");
        assert_eq!(v.opt_f64("f", 0.0), 1.5);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
