//! Run configuration: a JSON-loadable description of a full training job
//! (problem, algorithm, hyper-parameters), plus the spec-string parsers the
//! CLI shares. JSON handling is the in-crate [`json`] module (offline
//! environment — no serde).

pub mod json;

use crate::algorithms::{AlgorithmKind, HyperParams};
use crate::optim::{LrSchedule, Prox};
use json::Json;

/// Which workload to run.
#[derive(Clone, Debug, PartialEq)]
pub enum ProblemConfig {
    /// §5.1 linear regression.
    Linreg { rows: usize, dim: usize, lambda: f32, data_seed: u64 },
    /// Synthetic-MNIST MLP (Fig. 4 stand-in).
    MnistMlp { n_examples: usize, hidden: Vec<usize>, data_seed: u64 },
    /// Synthetic-CIFAR MLP (Fig. 5 stand-in).
    CifarMlp { n_examples: usize, hidden: Vec<usize>, data_seed: u64 },
    /// AOT transformer LM via PJRT artifacts (see `python/compile`).
    TransformerLm { artifact_dir: String, corpus_len: usize, data_seed: u64 },
}

impl ProblemConfig {
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let kind = v.req_str("kind")?;
        let seed = v.opt_u64("data_seed", 42);
        Ok(match kind {
            "linreg" => ProblemConfig::Linreg {
                rows: v.req_usize("rows")?,
                dim: v.req_usize("dim")?,
                lambda: v.req_f64("lambda")? as f32,
                data_seed: seed,
            },
            "mnist_mlp" => ProblemConfig::MnistMlp {
                n_examples: v.opt_usize("n_examples", 4096),
                hidden: parse_usize_array(v.get("hidden"), &[256, 64])?,
                data_seed: seed,
            },
            "cifar_mlp" => ProblemConfig::CifarMlp {
                n_examples: v.opt_usize("n_examples", 2048),
                hidden: parse_usize_array(v.get("hidden"), &[512, 256])?,
                data_seed: seed,
            },
            "transformer_lm" => ProblemConfig::TransformerLm {
                artifact_dir: v.opt_str("artifact_dir", "artifacts").to_string(),
                corpus_len: v.opt_usize("corpus_len", 200_000),
                data_seed: seed,
            },
            other => anyhow::bail!("unknown problem kind '{other}'"),
        })
    }
}

fn parse_usize_array(v: Option<&Json>, default: &[usize]) -> anyhow::Result<Vec<usize>> {
    match v {
        None => Ok(default.to_vec()),
        Some(j) => j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|e| e.as_usize().ok_or_else(|| anyhow::anyhow!("expected integer")))
            .collect(),
    }
}

/// Parse `none` | `l1[:λ]` | `l2[:λ]` | `box[:r]`.
pub fn parse_prox(spec: &str) -> anyhow::Result<Prox> {
    let parts: Vec<&str> = spec.split(':').collect();
    Ok(match parts[0] {
        "none" | "" => Prox::None,
        "l1" => Prox::L1 { lambda: parts.get(1).map_or(Ok(1e-4), |s| s.parse())? },
        "l2" => Prox::L2 { lambda: parts.get(1).map_or(Ok(1e-4), |s| s.parse())? },
        "box" => Prox::BoxConstraint { radius: parts.get(1).map_or(Ok(1.0), |s| s.parse())? },
        other => anyhow::bail!("unknown prox spec '{other}'"),
    })
}

/// Parse `const` | `decay[:factor[:every]]` | `warmup[:rounds]`.
pub fn parse_schedule(spec: &str, base: f32) -> anyhow::Result<LrSchedule> {
    let parts: Vec<&str> = spec.split(':').collect();
    Ok(match parts[0] {
        "const" | "constant" => LrSchedule::Constant(base),
        "decay" => LrSchedule::StepDecay {
            base,
            factor: parts.get(1).map_or(Ok(0.1), |s| s.parse())?,
            every: parts.get(2).map_or(Ok(25), |s| s.parse())?,
        },
        "warmup" => LrSchedule::Warmup {
            base,
            warmup: parts.get(1).map_or(Ok(100), |s| s.parse())?,
        },
        other => anyhow::bail!("unknown schedule spec '{other}'"),
    })
}

/// Hyper-parameter block of a job config.
#[derive(Clone, Debug)]
pub struct HyperConfig {
    pub lr: f32,
    pub alpha: f32,
    pub beta: f32,
    pub eta: f32,
    pub momentum: f32,
    pub worker_compressor: String,
    pub master_compressor: String,
    pub prox: String,
    pub schedule: Option<String>,
}

impl HyperConfig {
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            lr: v.req_f64("lr")? as f32,
            alpha: v.opt_f64("alpha", 0.1) as f32,
            beta: v.opt_f64("beta", 1.0) as f32,
            eta: v.opt_f64("eta", 1.0) as f32,
            momentum: v.opt_f64("momentum", 0.0) as f32,
            worker_compressor: v.opt_str("worker_compressor", "ternary:256").to_string(),
            master_compressor: v.opt_str("master_compressor", "ternary:256").to_string(),
            prox: v.opt_str("prox", "none").to_string(),
            schedule: v.get("schedule").and_then(Json::as_str).map(str::to_string),
        })
    }

    pub fn to_hyperparams(&self) -> anyhow::Result<HyperParams> {
        Ok(HyperParams {
            lr: self.lr,
            alpha: self.alpha,
            beta: self.beta,
            eta: self.eta,
            momentum: self.momentum,
            worker_compressor: self.worker_compressor.clone(),
            master_compressor: self.master_compressor.clone(),
            prox: parse_prox(&self.prox)?,
            schedule: match &self.schedule {
                None => None,
                Some(s) => Some(parse_schedule(s, self.lr)?),
            },
        })
    }
}

/// A complete training job.
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub problem: ProblemConfig,
    pub algorithm: String,
    pub hyper: HyperConfig,
    pub n_workers: usize,
    pub iters: usize,
    pub minibatch: Option<usize>,
    pub eval_every: usize,
    pub seed: u64,
    /// Wire codec name (`"fixed"` | `"entropy"`), parsed into
    /// [`crate::compression::WireCodec`] by the CLI layer.
    pub wire_codec: String,
}

impl JobConfig {
    pub fn from_json(s: &str) -> anyhow::Result<Self> {
        let v = Json::parse(s)?;
        Ok(Self {
            problem: ProblemConfig::from_json(
                v.get("problem").ok_or_else(|| anyhow::anyhow!("missing 'problem'"))?,
            )?,
            algorithm: v.req_str("algorithm")?.to_string(),
            hyper: HyperConfig::from_json(
                v.get("hyper").ok_or_else(|| anyhow::anyhow!("missing 'hyper'"))?,
            )?,
            n_workers: v.req_usize("n_workers")?,
            iters: v.req_usize("iters")?,
            minibatch: v.get("minibatch").and_then(Json::as_usize),
            eval_every: v.opt_usize("eval_every", 10),
            seed: v.opt_u64("seed", 42),
            wire_codec: v.opt_str("wire_codec", "fixed").to_string(),
        })
    }

    pub fn from_file(path: &str) -> anyhow::Result<Self> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }

    pub fn algorithm_kind(&self) -> anyhow::Result<AlgorithmKind> {
        self.algorithm.parse()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_job_parses() {
        let s = r#"{
            "problem": {"kind": "linreg", "rows": 1200, "dim": 500, "lambda": 0.1},
            "algorithm": "dore",
            "hyper": {"lr": 0.05, "alpha": 0.1, "beta": 1.0, "eta": 1.0,
                      "worker_compressor": "ternary:256", "schedule": "decay:0.1:25"},
            "n_workers": 20,
            "iters": 1000,
            "minibatch": 64
        }"#;
        let job = JobConfig::from_json(s).unwrap();
        assert_eq!(job.n_workers, 20);
        assert_eq!(job.minibatch, Some(64));
        assert_eq!(job.algorithm_kind().unwrap(), AlgorithmKind::Dore);
        assert_eq!(
            job.problem,
            ProblemConfig::Linreg { rows: 1200, dim: 500, lambda: 0.1, data_seed: 42 }
        );
        let hp = job.hyper.to_hyperparams().unwrap();
        assert!(hp.schedule.is_some());
    }

    #[test]
    fn minimal_json_uses_defaults() {
        let s = r#"{
            "problem": {"kind": "mnist_mlp"},
            "algorithm": "diana",
            "hyper": {"lr": 0.1},
            "n_workers": 4,
            "iters": 100
        }"#;
        let job = JobConfig::from_json(s).unwrap();
        assert_eq!(job.eval_every, 10);
        assert_eq!(job.minibatch, None);
        match &job.problem {
            ProblemConfig::MnistMlp { hidden, n_examples, .. } => {
                assert_eq!(hidden, &[256, 64]);
                assert_eq!(*n_examples, 4096);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prox_and_schedule_specs() {
        assert_eq!(parse_prox("l1:0.5").unwrap(), Prox::L1 { lambda: 0.5 });
        assert_eq!(parse_prox("none").unwrap(), Prox::None);
        assert!(parse_prox("huh").is_err());
        match parse_schedule("decay:0.1:25", 0.1).unwrap() {
            LrSchedule::StepDecay { factor, every, .. } => {
                assert_eq!(factor, 0.1);
                assert_eq!(every, 25);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse_schedule("huh", 0.1).is_err());
    }

    #[test]
    fn bad_configs_are_rejected() {
        assert!(JobConfig::from_json("{}").is_err());
        assert!(JobConfig::from_json(
            r#"{"problem": {"kind": "nope"}, "algorithm": "dore",
                "hyper": {"lr": 0.1}, "n_workers": 1, "iters": 1}"#
        )
        .is_err());
    }
}
