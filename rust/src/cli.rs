//! Shared command-line plumbing for the `dore` and `dore-worker` binaries.
//!
//! Both binaries must construct **the same** [`Problem`] and [`TrainSpec`]
//! from the same flags — the registration handshake fingerprints the spec
//! ([`crate::engine::protocol::spec_fingerprint`]) and rejects a fleet
//! whose members were launched with different training flags. Keeping the
//! flag → spec mapping in one module makes "same flags ⇒ same fingerprint"
//! true by construction.
//!
//! Flag parsing is hand-rolled (offline environment, no clap): every flag
//! is `--name value` except bare booleans (e.g. `--distributed`,
//! `--rejoin`).

use crate::algorithms::HyperParams;
use crate::config::{parse_prox, parse_schedule};
use crate::data::synth;
use crate::engine::{FaultPlan, Participation, StalePolicy, TrainSpec};
use crate::models::mlp::{Mlp, MlpArch};
use crate::models::Problem;
use crate::runtime::lm::TransformerLm;
use std::collections::BTreeMap;
use std::sync::Arc;

/// `--key value` flags plus bare boolean flags.
pub struct Flags {
    vals: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Flags {
    pub fn parse(args: &[String]) -> anyhow::Result<Self> {
        let mut vals = BTreeMap::new();
        let mut bools = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            anyhow::ensure!(a.starts_with("--"), "unexpected argument '{a}'");
            let key = a.trim_start_matches("--").to_string();
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                vals.insert(key, args[i + 1].clone());
                i += 2;
            } else {
                bools.push(key);
                i += 1;
            }
        }
        Ok(Self { vals, bools })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.vals.get(key).map(|s| s.as_str())
    }

    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--{key} {s}: {e}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }
}

/// The named benchmark problems both binaries can build. Constructed
/// purely from `(name, workers, seed)`, so a master and its remote
/// workers hold bit-identical data shards.
pub fn build_problem(name: &str, workers: usize, seed: u64) -> anyhow::Result<Arc<dyn Problem>> {
    Ok(match name {
        "linreg" => Arc::new(synth::linreg_problem(1200, 500, workers, 0.1, seed)),
        "mnist" => {
            let (tr, te) = synth::mnist_like(4096, seed).split_test(512);
            Arc::new(Mlp::new(MlpArch::new(&[784, 256, 64, 10]), tr, Some(te), workers, seed))
        }
        "cifar" => {
            let (tr, te) = synth::cifar_like(2048, seed).split_test(256);
            Arc::new(Mlp::new(MlpArch::new(&[3072, 512, 256, 10]), tr, Some(te), workers, seed))
        }
        "transformer" => {
            let corpus = synth::markov_corpus(200_000, 512, seed);
            Arc::new(TransformerLm::load(
                crate::runtime::default_artifact_dir(),
                corpus,
                workers,
                seed,
            )?)
        }
        other => anyhow::bail!("unknown problem '{other}' (linreg|mnist|cifar|transformer)"),
    })
}

/// Build a [`TrainSpec`] from the flag set (the non-config-file path of
/// `dore train`, and the only path of `dore-worker`). Includes the
/// cross-cutting overrides from [`apply_spec_overrides`].
pub fn train_spec(f: &Flags) -> anyhow::Result<TrainSpec> {
    let lr: f32 = f.num("lr", 0.05)?;
    let compressor = f.get("compressor").unwrap_or("ternary:256").to_string();
    let hp = HyperParams {
        lr,
        alpha: f.num("alpha", 0.1)?,
        beta: f.num("beta", 1.0)?,
        eta: f.num("eta", 1.0)?,
        momentum: f.num("momentum", 0.0)?,
        worker_compressor: compressor.clone(),
        master_compressor: compressor,
        prox: parse_prox(f.get("prox").unwrap_or("none"))?,
        schedule: match f.get("schedule") {
            None => None,
            Some(s) => Some(parse_schedule(s, lr)?),
        },
    };
    let mut spec = TrainSpec {
        algo: f.get("algorithm").unwrap_or("dore").parse()?,
        hp,
        iters: f.num("iters", 1000)?,
        minibatch: f.get("minibatch").map(|s| s.parse()).transpose()?,
        eval_every: f.num("eval-every", 10)?,
        seed: f.num("seed", 42)?,
        ..Default::default()
    };
    apply_spec_overrides(f, &mut spec)?;
    Ok(spec)
}

/// The spec knobs that apply on every entry path (flag set *and* config
/// file): participation, stale policy, fault injection, reduction threads,
/// pipeline depth, wire codec.
pub fn apply_spec_overrides(f: &Flags, spec: &mut TrainSpec) -> anyhow::Result<()> {
    // partial participation + stale-uplink policy apply on either path
    // and on every transport; `fastest:<K>` needs tcp or simnet
    if let Some(p) = f.get("participation") {
        spec.participation = p.parse::<Participation>()?;
    }
    if let Some(s) = f.get("stale") {
        spec.stale = s.parse::<StalePolicy>()?;
    }
    // deterministic failure injection: a seeded crash/rejoin schedule —
    // a pure function of (seed, round, slot), identical on every transport
    if let Some(s) = f.get("fault") {
        spec.fault = s.parse::<FaultPlan>()?;
    }
    // master-side sharded reduction: thread count only — results are
    // bit-identical for every value (0 = all available cores)
    spec.reduce_threads = f.num("reduce-threads", 1)?;
    // pipelined rounds: depth 1 (default) is the classic synchronous
    // schedule; D ≥ 2 overlaps round t+1's uplink with round t's master
    // pass at the price of a (D−1)-round-stale gradient — deterministic
    // and transport-independent either way
    spec.pipeline_depth = f.num("pipeline-depth", 1)?;
    // wire codec: what the frames on the wire look like — entropy coding
    // shrinks them (never grows, by the whole-frame escape) without
    // touching the trajectory; only the bit accounting moves
    if let Some(w) = f.get("wire-codec") {
        spec.wire_codec = w.parse()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn flags_split_values_and_booleans() {
        let f = Flags::parse(&args(&["--lr", "0.1", "--distributed", "--iters", "5"])).unwrap();
        assert_eq!(f.get("lr"), Some("0.1"));
        assert_eq!(f.num::<usize>("iters", 0).unwrap(), 5);
        assert!(f.flag("distributed"));
        assert!(!f.flag("lr"));
        assert!(Flags::parse(&args(&["stray"])).is_err());
    }

    #[test]
    fn same_flags_build_identical_specs() {
        // the fleet contract: master and dore-worker hand the same flag
        // set to train_spec and must land on the same fingerprint
        use crate::engine::protocol::spec_fingerprint;
        let a = args(&["--lr", "0.07", "--iters", "30", "--participation", "fastest:2"]);
        let s1 = train_spec(&Flags::parse(&a).unwrap()).unwrap();
        let s2 = train_spec(&Flags::parse(&a).unwrap()).unwrap();
        assert_eq!(spec_fingerprint(&s1, 500, 4), spec_fingerprint(&s2, 500, 4));
        assert_eq!(s1.participation, Participation::Fastest { k: 2 });
        // a differing flag moves the fingerprint
        let b = args(&["--lr", "0.07", "--iters", "31", "--participation", "fastest:2"]);
        let s3 = train_spec(&Flags::parse(&b).unwrap()).unwrap();
        assert_ne!(spec_fingerprint(&s1, 500, 4), spec_fingerprint(&s3, 500, 4));
    }
}
