//! Synthetic dataset generation and sharding.
//!
//! The paper's real datasets (MNIST, CIFAR10) are not available in this
//! environment; per the substitution rule, [`synth`] generates datasets of
//! the same *shape* (dimensions, class count, per-worker batch structure)
//! so that the communication/compression path — the thing the experiments
//! actually measure — is exercised identically. See DESIGN.md §2.

pub mod synth;

use crate::F;

/// A labelled classification dataset (dense features, integer labels).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: Vec<F>,
    pub labels: Vec<u32>,
    pub n: usize,
    pub input_dim: usize,
    pub n_classes: usize,
}

impl Dataset {
    pub fn example(&self, i: usize) -> (&[F], u32) {
        (
            &self.features[i * self.input_dim..(i + 1) * self.input_dim],
            self.labels[i],
        )
    }

    /// Split off the last `n_test` examples as a test set.
    pub fn split_test(mut self, n_test: usize) -> (Dataset, Dataset) {
        assert!(n_test < self.n);
        let n_train = self.n - n_test;
        let test = Dataset {
            features: self.features.split_off(n_train * self.input_dim),
            labels: self.labels.split_off(n_train),
            n: n_test,
            input_dim: self.input_dim,
            n_classes: self.n_classes,
        };
        self.n = n_train;
        (self, test)
    }
}

/// Contiguous even sharding of `n` items over `w` workers (remainder spread
/// over the first shards, matching the paper's "allocated evenly").
pub fn shard_ranges(n: usize, w: usize) -> Vec<(usize, usize)> {
    let base = n / w;
    let extra = n % w;
    let mut out = Vec::with_capacity(w);
    let mut lo = 0;
    for i in 0..w {
        let len = base + usize::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_and_partition() {
        for (n, w) in [(10, 3), (20, 4), (7, 7), (100, 9)] {
            let s = shard_ranges(n, w);
            assert_eq!(s.len(), w);
            assert_eq!(s[0].0, 0);
            assert_eq!(s[w - 1].1, n);
            for i in 1..w {
                assert_eq!(s[i].0, s[i - 1].1);
            }
            let sizes: Vec<usize> = s.iter().map(|(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "uneven shards {sizes:?}");
        }
    }

    #[test]
    fn split_test_partitions() {
        let ds = Dataset {
            features: (0..20).map(|i| i as F).collect(),
            labels: (0..10).collect(),
            n: 10,
            input_dim: 2,
            n_classes: 10,
        };
        let (tr, te) = ds.split_test(3);
        assert_eq!(tr.n, 7);
        assert_eq!(te.n, 3);
        assert_eq!(te.labels, vec![7, 8, 9]);
        assert_eq!(te.example(0).0, &[14.0, 15.0]);
    }
}
