//! Synthetic workload generators matching the paper's experimental shapes.

use super::Dataset;
use crate::compression::Xoshiro256;
use crate::models::linalg;
use crate::models::linreg::LinReg;
use crate::F;

/// §5.1 linear-regression problem: random `A ∈ R^{rows×dim}`, random
/// planted solution `x*`, `b ~ N(A x*, noise)`; rows sharded evenly over
/// `n_workers`. The paper uses `rows = 1200, dim = 500, n_workers = 20`.
pub fn linreg_problem(rows: usize, dim: usize, n_workers: usize, lambda: F, seed: u64) -> LinReg {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut a = vec![0.0; rows * dim];
    for v in a.iter_mut() {
        *v = rng.next_gaussian() / (dim as F).sqrt();
    }
    let x_star: Vec<F> = (0..dim).map(|_| rng.next_gaussian()).collect();
    let mut b = vec![0.0; rows];
    linalg::matvec(&a, rows, dim, &x_star, &mut b);
    for v in b.iter_mut() {
        *v += 0.05 * rng.next_gaussian(); // observation noise
    }
    LinReg::new(a, b, rows, dim, lambda, n_workers)
}

/// The paper's exact Fig. 3 shape: `A ∈ R^{1200×500}`, 20 workers.
pub fn paper_linreg(seed: u64) -> LinReg {
    linreg_problem(1200, 500, 20, 0.1, seed)
}

/// Gaussian-cluster classification dataset standing in for MNIST
/// (`input_dim = 784`, 10 classes) or CIFAR10 (`input_dim = 3072`): each
/// class `c` has a random unit-norm prototype `μ_c`; examples are
/// `μ_c + spread · ε`. Linearly-nonseparable enough (spread ≥ 1) that an
/// MLP trains nontrivially, while small enough to run hundreds of epochs
/// in benches.
pub fn cluster_classification(
    n: usize,
    input_dim: usize,
    n_classes: usize,
    spread: F,
    seed: u64,
) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let inv = 1.0 / (input_dim as F).sqrt();
    let protos: Vec<F> = (0..n_classes * input_dim)
        .map(|_| rng.next_gaussian() * inv * 4.0)
        .collect();
    let mut features = vec![0.0; n * input_dim];
    let mut labels = vec![0u32; n];
    for i in 0..n {
        let c = rng.next_below(n_classes);
        labels[i] = c as u32;
        let proto = &protos[c * input_dim..(c + 1) * input_dim];
        let row = &mut features[i * input_dim..(i + 1) * input_dim];
        for (r, &p) in row.iter_mut().zip(proto.iter()) {
            *r = p + spread * rng.next_gaussian() * inv;
        }
    }
    Dataset {
        features,
        labels,
        n,
        input_dim,
        n_classes,
    }
}

/// MNIST-shaped synthetic set (784 → 10).
pub fn mnist_like(n: usize, seed: u64) -> Dataset {
    cluster_classification(n, 784, 10, 2.0, seed)
}

/// CIFAR10-shaped synthetic set (3072 → 10), harder (larger spread).
pub fn cifar_like(n: usize, seed: u64) -> Dataset {
    cluster_classification(n, 3072, 10, 3.0, seed)
}

/// Token stream for the transformer LM: a synthetic order-2 Markov corpus
/// over `vocab` symbols so the LM has real structure to learn (loss drops
/// well below `ln(vocab)`).
pub fn markov_corpus(len: usize, vocab: usize, seed: u64) -> Vec<u32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    // sparse transition structure: each (prev) maps to 4 likely successors
    let succ: Vec<u32> = (0..vocab * 4).map(|_| rng.next_below(vocab) as u32).collect();
    let mut out = Vec::with_capacity(len);
    let mut prev = 0usize;
    for _ in 0..len {
        let t = if rng.next_f32() < 0.85 {
            succ[prev * 4 + rng.next_below(4)]
        } else {
            rng.next_below(vocab) as u32
        };
        out.push(t);
        prev = t as usize;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Problem;

    #[test]
    fn linreg_shapes() {
        let p = linreg_problem(60, 10, 3, 0.1, 1);
        assert_eq!(p.dim(), 10);
        assert_eq!(p.n_workers(), 3);
        assert!(p.optimum().is_some());
    }

    #[test]
    fn clusters_have_all_classes() {
        let ds = cluster_classification(500, 16, 10, 1.0, 3);
        let mut seen = [false; 10];
        for &l in &ds.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(ds.features.len(), 500 * 16);
    }

    #[test]
    fn markov_corpus_in_vocab_and_structured() {
        let v = 64;
        let c = markov_corpus(10_000, v, 5);
        assert!(c.iter().all(|&t| (t as usize) < v));
        // structure check: empirical bigram entropy must be well below log v
        let mut counts = vec![0u32; v * v];
        for w in c.windows(2) {
            counts[w[0] as usize * v + w[1] as usize] += 1;
        }
        let mut h = 0.0f64;
        let total = (c.len() - 1) as f64;
        // conditional entropy H(next | prev)
        for p in 0..v {
            let row = &counts[p * v..(p + 1) * v];
            let rn: u32 = row.iter().sum();
            if rn == 0 {
                continue;
            }
            for &cnt in row {
                if cnt > 0 {
                    let pj = cnt as f64 / total;
                    h -= pj * (cnt as f64 / rn as f64).ln();
                }
            }
        }
        assert!(h < 0.8 * (v as f64).ln(), "H(next|prev)={h}, ln v={}", (v as f64).ln());
    }

    #[test]
    fn deterministic_generation() {
        let a = mnist_like(50, 9);
        let b = mnist_like(50, 9);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }
}
