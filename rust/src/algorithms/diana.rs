//! DIANA (Mishchenko et al., 2019): gradient-*difference* compression.
//! Worker `i` keeps a state `h_i` tracking its local gradient and uploads
//! `Q(g_i − h_i)`; both sides update `h ← h + α·Q(Δ)`. Because
//! `h_i → ∇f_i(x*)`, the compressed residual vanishes and DIANA converges
//! linearly (Fig. 3) — but the model broadcast stays dense, so at most 50 %
//! of communication is saved (§1). DIANA is exactly DORE with an identity
//! master-side compressor.

use super::{digest_f32, HyperParams, MasterNode, WorkerNode};
use crate::compression::{BoxedCompressor, Compressed, Xoshiro256};
use crate::engine::reduce::ReducePool;
use crate::models::linalg;
use crate::F;

pub struct DianaWorker {
    x: Vec<F>,
    h: Vec<F>,
    delta: Vec<F>,
    alpha: F,
    q: BoxedCompressor,
    last_norm: f64,
}

impl DianaWorker {
    pub fn new(x0: &[F], q: BoxedCompressor, alpha: F) -> Self {
        Self {
            x: x0.to_vec(),
            h: vec![0.0; x0.len()],
            delta: vec![0.0; x0.len()],
            alpha,
            q,
            last_norm: 0.0,
        }
    }
}

impl WorkerNode for DianaWorker {
    fn round(&mut self, _round: usize, grad: &[F], rng: &mut Xoshiro256) -> Compressed {
        // Δ_i = g_i − h_i
        for (d, (&g, &h)) in self.delta.iter_mut().zip(grad.iter().zip(self.h.iter())) {
            *d = g - h;
        }
        self.last_norm = linalg::norm2(&self.delta);
        let up = self.q.compress(&self.delta, rng);
        // h_i ← h_i + α·Q(Δ_i)
        up.add_scaled_into(self.alpha, &mut self.h);
        up
    }

    fn apply_downlink(&mut self, _round: usize, down: &Compressed) {
        self.x.fill(0.0);
        down.add_scaled_into(1.0, &mut self.x);
    }

    fn on_reused(&mut self, _round: usize, payload: &Compressed) {
        // the master folded the replayed Δ̂ into its h; mirror it so
        // h = (1/n)Σ h_i stays exact
        payload.add_scaled_into(self.alpha, &mut self.h);
    }

    fn residual_digest(&self) -> u64 {
        digest_f32(&self.h)
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        vec![("h".into(), self.h.clone())]
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "h" => super::restore_vec("h", &mut self.h, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for a DIANA worker"),
            }
        }
        Ok(())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn last_compressed_norm(&self) -> f64 {
        self.last_norm
    }
}

pub struct DianaMaster {
    x: Vec<F>,
    /// `h = (1/n) Σ h_i`, tracked exactly as the workers do.
    h: Vec<F>,
    ghat: Vec<F>,
    vel: Vec<F>,
    n: usize,
    hp: HyperParams,
    pool: ReducePool,
}

impl DianaMaster {
    pub fn new(x0: &[F], n: usize, hp: HyperParams) -> Self {
        Self {
            x: x0.to_vec(),
            h: vec![0.0; x0.len()],
            ghat: vec![0.0; x0.len()],
            vel: Vec::new(),
            n,
            hp,
            pool: ReducePool::serial(),
        }
    }
}

impl MasterNode for DianaMaster {
    fn round(
        &mut self,
        round: usize,
        uplinks: &[Option<Compressed>],
        _rng: &mut Xoshiro256,
    ) -> Compressed {
        debug_assert_eq!(uplinks.len(), self.n);
        // ĝ = h + (1/n) Σ_{i∈S} Q(Δ_i) and h ← h + α·(1/n) Σ_{i∈S} Q(Δ_i),
        // fused into one sweep over the pool's dimension shards. An absent
        // slot is Δ̂_i = 0 — its stale h_i is already inside h — so the
        // normalization stays 1/n under partial participation. Within each
        // shard the uplinks decode straight into the (ĝ, h) slices in slot
        // order, so every coordinate accumulates exactly as on the serial
        // path for any reduce-thread count.
        let inv = 1.0 / self.n as F;
        let alpha_inv = self.hp.alpha * inv;
        let pool = self.pool.clone();
        {
            let (ghat, h) = (&mut self.ghat, &mut self.h);
            // NOTE: kept as two per-target passes (not the fused
            // `add_scaled2_range_into`) — DIANA's historical grouping
            // rounds `inv·(norm·t)` and `alpha_inv·(norm·t)` separately,
            // and the golden trajectories pin that expression tree.
            pool.sweep2(ghat, h, |lo, gc, hc| {
                gc.copy_from_slice(hc);
                for m in uplinks.iter().flatten() {
                    m.add_scaled_range_into(inv, lo, gc);
                }
                for m in uplinks.iter().flatten() {
                    m.add_scaled_range_into(alpha_inv, lo, hc);
                }
            });
        }
        let gamma = self.hp.lr_at(round);
        // x ← prox_{γR}(x − γ·step), momentum fold included, swept over
        // the pool's dimension shards (§Perf).
        super::dense_step_tail(
            &pool,
            -gamma,
            gamma,
            self.hp.momentum,
            self.hp.prox,
            &self.ghat,
            &mut self.vel,
            &mut self.x,
        );
        Compressed::Dense(self.x.clone())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        let mut aux = vec![("h".into(), self.h.clone())];
        if !self.vel.is_empty() {
            aux.push(("vel".into(), self.vel.clone()));
        }
        aux
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "h" => super::restore_vec("h", &mut self.h, v)?,
                "vel" => super::restore_vec("vel", &mut self.vel, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for the DIANA master"),
            }
        }
        Ok(())
    }

    fn set_reduce_pool(&mut self, pool: ReducePool) {
        self.pool = pool;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Identity, PNorm, PNormQuantizer};
    use std::sync::Arc;

    #[test]
    fn worker_state_ema_property() {
        // With identity compression, h^{k+1} = (1-α)h + αg exactly (Lemma 1).
        let x0 = vec![0.0; 3];
        let mut w = DianaWorker::new(&x0, Arc::new(Identity), 0.25);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let g = vec![4.0, -8.0, 0.0];
        w.round(0, &g, &mut rng);
        assert_eq!(w.h, vec![1.0, -2.0, 0.0]);
        w.round(1, &g, &mut rng);
        assert_eq!(w.h, vec![1.75, -3.5, 0.0]);
    }

    #[test]
    fn master_h_mirrors_worker_h() {
        let x0 = vec![0.0; 8];
        let q = Arc::new(PNormQuantizer::new(PNorm::Inf, 4));
        let hp = HyperParams { alpha: 0.1, lr: 0.0, ..HyperParams::paper_defaults() };
        let mut w = DianaWorker::new(&x0, q, 0.1);
        let mut m = DianaMaster::new(&x0, 1, hp);
        let mut wrng = Xoshiro256::for_site(1, 1, 0);
        for k in 0..5 {
            let g: Vec<F> = (0..8).map(|j| ((j + k) as F * 0.3).sin()).collect();
            let up = w.round(k, &g, &mut wrng);
            let mut mrng = Xoshiro256::for_site(1, 0, k as u64);
            m.round(k, &[Some(up)], &mut mrng);
            for (a, b) in w.h.iter().zip(&m.h) {
                assert!((a - b).abs() < 1e-6, "h desync at round {k}");
            }
        }
    }

    #[test]
    fn h_stays_in_sync_across_skipped_and_reused_rounds() {
        let x0 = vec![0.0; 6];
        let q = Arc::new(PNormQuantizer::new(PNorm::Inf, 3));
        let hp = HyperParams { alpha: 0.2, lr: 0.05, ..HyperParams::paper_defaults() };
        let mut ws: Vec<DianaWorker> =
            (0..2).map(|_| DianaWorker::new(&x0, q.clone(), 0.2)).collect();
        let mut m = DianaMaster::new(&x0, 2, hp);
        let mut last: Vec<Option<Compressed>> = vec![None, None];
        for k in 0..10usize {
            // worker 1 sits out odd rounds; even rounds everyone uploads
            let mask = [true, k % 2 == 0];
            let mut skipped_digest: Option<u64> = None;
            let mut slots: Vec<Option<Compressed>> = Vec::new();
            for (i, w) in ws.iter_mut().enumerate() {
                if mask[i] {
                    let g: Vec<F> = (0..6).map(|j| ((i + j + k) as F * 0.4).sin()).collect();
                    let mut rng = Xoshiro256::for_site(6, 1 + i as u64, k as u64);
                    let up = w.round(k, &g, &mut rng);
                    last[i] = Some(up.clone());
                    slots.push(Some(up));
                } else if k % 4 == 1 {
                    // reuse-last on some skipped rounds
                    let stale = last[i].clone().unwrap();
                    w.on_reused(k, &stale);
                    slots.push(Some(stale));
                } else {
                    skipped_digest = Some(w.residual_digest());
                    slots.push(None);
                }
            }
            let mut mrng = Xoshiro256::for_site(6, 0, k as u64);
            let down = m.round(k, &slots, &mut mrng);
            for w in ws.iter_mut() {
                w.apply_downlink(k, &down);
            }
            if let Some(before) = skipped_digest {
                // plain skip: the whole round must leave the absentee's h
                // untouched (the dense downlink replaces x only)
                assert_eq!(ws[1].residual_digest(), before, "skip moved h at round {k}");
            }
            // the central invariant: master h == (1/n) Σ worker h, every round
            for j in 0..6 {
                let avg = (ws[0].h[j] + ws[1].h[j]) / 2.0;
                assert!((m.h[j] - avg).abs() < 1e-6, "h desync at round {k} coord {j}");
            }
        }
    }

    #[test]
    fn diana_with_identity_equals_gd() {
        let x0 = vec![2.0];
        let hp = HyperParams { lr: 0.5, alpha: 1.0, ..HyperParams::paper_defaults() };
        let mut w = DianaWorker::new(&x0, Arc::new(Identity), 1.0);
        let mut m = DianaMaster::new(&x0, 1, hp);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let up = w.round(0, &[2.0], &mut rng);
        let down = m.round(0, &[Some(up)], &mut rng);
        w.apply_downlink(0, &down);
        assert_eq!(m.model(), &[1.0]); // 2 − 0.5·2
        assert_eq!(w.model(), m.model());
    }
}
