//! MEM-SGD (Stich et al., 2018): QSGD with worker-side error feedback.
//! Each worker accumulates its compression error and folds it into the next
//! upload: `p_i = γ·g_i + e_i; send Q(p_i); e_i = p_i − Q(p_i)`.
//! The master adds the decoded average directly to the model (the γ is
//! already inside the uplink, which is what makes the memory mechanism
//! step-size-correct under schedules) and broadcasts the dense model.

use super::{average_present, digest_f32, HyperParams, MasterNode, WorkerNode};
use crate::compression::{BoxedCompressor, Compressed, Xoshiro256};
use crate::engine::reduce::ReducePool;
use crate::models::linalg;
use crate::F;

pub struct MemSgdWorker {
    x: Vec<F>,
    e: Vec<F>,
    buf: Vec<F>,
    q: BoxedCompressor,
    last_norm: f64,
    hp: HyperParams,
}

impl MemSgdWorker {
    pub fn new(x0: &[F], q: BoxedCompressor) -> Self {
        Self {
            x: x0.to_vec(),
            e: vec![0.0; x0.len()],
            buf: vec![0.0; x0.len()],
            q,
            last_norm: 0.0,
            hp: HyperParams::paper_defaults(),
        }
    }

    pub fn with_hp(x0: &[F], q: BoxedCompressor, hp: HyperParams) -> Self {
        Self { hp, ..Self::new(x0, q) }
    }
}

impl WorkerNode for MemSgdWorker {
    fn round(&mut self, round: usize, grad: &[F], rng: &mut Xoshiro256) -> Compressed {
        let gamma = self.hp.lr_at(round);
        // p = γ g + e
        self.buf.copy_from_slice(&self.e);
        linalg::axpy(gamma, grad, &mut self.buf);
        self.last_norm = linalg::norm2(&self.buf);
        let up = self.q.compress(&self.buf, rng);
        // e = p − Q(p)
        self.e.copy_from_slice(&self.buf);
        up.add_scaled_into(-1.0, &mut self.e);
        up
    }

    fn apply_downlink(&mut self, _round: usize, down: &Compressed) {
        self.x.fill(0.0);
        down.add_scaled_into(1.0, &mut self.x);
    }

    // a replayed frame was already error-compensated when first sent; the
    // worker's e_i needs no correction, so the default no-op `on_reused`
    // is the right semantics.

    fn residual_digest(&self) -> u64 {
        digest_f32(&self.e)
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        vec![("e".into(), self.e.clone())]
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "e" => super::restore_vec("e", &mut self.e, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for a MEM-SGD worker"),
            }
        }
        Ok(())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn last_compressed_norm(&self) -> f64 {
        self.last_norm
    }
}

pub struct MemSgdMaster {
    x: Vec<F>,
    dbar: Vec<F>,
    n: usize,
    hp: HyperParams,
    pool: ReducePool,
}

impl MemSgdMaster {
    pub fn new(x0: &[F], n: usize, hp: HyperParams) -> Self {
        Self { x: x0.to_vec(), dbar: vec![0.0; x0.len()], n, hp, pool: ReducePool::serial() }
    }
}

impl MasterNode for MemSgdMaster {
    fn round(
        &mut self,
        round: usize,
        uplinks: &[Option<Compressed>],
        _rng: &mut Xoshiro256,
    ) -> Compressed {
        debug_assert_eq!(uplinks.len(), self.n);
        // partial participation: average over whoever showed up
        average_present(uplinks, &mut self.dbar, &self.pool);
        // the γ is inside the uplinks: x ← x − mean(Q(γg_i + e_i)), then
        // the prox — swept over the pool's dimension shards (§Perf).
        super::dense_step_tail(
            &self.pool,
            -1.0,
            self.hp.lr_at(round),
            0.0,
            self.hp.prox,
            &self.dbar,
            &mut Vec::new(),
            &mut self.x,
        );
        Compressed::Dense(self.x.clone())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        if let Some((name, _)) = aux.first() {
            anyhow::bail!("unknown aux vector '{name}' for the MEM-SGD master (it keeps none)");
        }
        Ok(())
    }

    fn set_reduce_pool(&mut self, pool: ReducePool) {
        self.pool = pool;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Identity, PNorm, PNormQuantizer};
    use std::sync::Arc;

    #[test]
    fn error_state_tracks_residual() {
        let x0 = vec![0.0; 4];
        let q = Arc::new(PNormQuantizer::new(PNorm::Inf, 4));
        let mut w = MemSgdWorker::with_hp(
            &x0,
            q,
            HyperParams { lr: 1.0, ..HyperParams::paper_defaults() },
        );
        let mut rng = Xoshiro256::seed_from_u64(2);
        let g = vec![1.0, 0.5, -0.25, 0.0];
        let up = w.round(0, &g, &mut rng);
        // e + Q(p) must equal p = γg (first round e=0)
        let mut rec = w.e.clone();
        up.add_scaled_into(1.0, &mut rec);
        for (r, &gi) in rec.iter().zip(&g) {
            assert!((r - gi).abs() < 1e-6);
        }
    }

    #[test]
    fn with_identity_compressor_equals_sgd() {
        let x0 = vec![1.0, -1.0];
        let hp = HyperParams { lr: 0.25, ..HyperParams::paper_defaults() };
        let mut w = MemSgdWorker::with_hp(&x0, Arc::new(Identity), hp.clone());
        let mut m = MemSgdMaster::new(&x0, 1, hp);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let up = w.round(0, &[4.0, 8.0], &mut rng);
        let down = m.round(0, &[Some(up)], &mut rng);
        w.apply_downlink(0, &down);
        assert_eq!(m.model(), &[0.0, -3.0]);
        // zero residual error with identity compression
        assert!(w.e.iter().all(|&v| v == 0.0));
    }
}
