//! QSGD (Alistarh et al., 2017): workers upload quantized gradients
//! `Q(g_i)`; the master averages the decoded gradients, steps, and
//! broadcasts the **dense** model (per §3.2 of the paper, gradient-only
//! schemes still pay 32·d on the downlink).
//!
//! Because `Q(g_i)` has variance ∝ ‖g_i‖² and `∇f_i(x*) ≠ 0` in general,
//! QSGD converges only to a neighbourhood of `x*` under a constant step
//! size — exactly the plateau Fig. 3 shows.

use super::{average_present, HyperParams, MasterNode, WorkerNode};
use crate::compression::{BoxedCompressor, Compressed, Xoshiro256};
use crate::engine::reduce::ReducePool;
use crate::models::linalg;
use crate::F;

pub struct QsgdWorker {
    x: Vec<F>,
    q: BoxedCompressor,
    last_norm: f64,
}

impl QsgdWorker {
    pub fn new(x0: &[F], q: BoxedCompressor) -> Self {
        Self { x: x0.to_vec(), q, last_norm: 0.0 }
    }
}

impl WorkerNode for QsgdWorker {
    fn round(&mut self, _round: usize, grad: &[F], rng: &mut Xoshiro256) -> Compressed {
        self.last_norm = linalg::norm2(grad);
        self.q.compress(grad, rng)
    }

    fn apply_downlink(&mut self, _round: usize, down: &Compressed) {
        self.x.fill(0.0);
        down.add_scaled_into(1.0, &mut self.x);
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        if let Some((name, _)) = aux.first() {
            anyhow::bail!("unknown aux vector '{name}' for a QSGD worker (it keeps none)");
        }
        Ok(())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn last_compressed_norm(&self) -> f64 {
        self.last_norm
    }
}

pub struct QsgdMaster {
    x: Vec<F>,
    gbar: Vec<F>,
    vel: Vec<F>,
    n: usize,
    hp: HyperParams,
    pool: ReducePool,
}

impl QsgdMaster {
    pub fn new(x0: &[F], n: usize, hp: HyperParams) -> Self {
        Self {
            x: x0.to_vec(),
            gbar: vec![0.0; x0.len()],
            vel: Vec::new(),
            n,
            hp,
            pool: ReducePool::serial(),
        }
    }
}

impl MasterNode for QsgdMaster {
    fn round(
        &mut self,
        round: usize,
        uplinks: &[Option<Compressed>],
        _rng: &mut Xoshiro256,
    ) -> Compressed {
        debug_assert_eq!(uplinks.len(), self.n);
        // partial participation: average over whoever showed up
        average_present(uplinks, &mut self.gbar, &self.pool);
        let gamma = self.hp.lr_at(round);
        // x ← prox_{γR}(x − γ·step), momentum fold included, swept over
        // the pool's dimension shards (§Perf).
        super::dense_step_tail(
            &self.pool,
            -gamma,
            gamma,
            self.hp.momentum,
            self.hp.prox,
            &self.gbar,
            &mut self.vel,
            &mut self.x,
        );
        Compressed::Dense(self.x.clone())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        if self.vel.is_empty() {
            Vec::new()
        } else {
            vec![("vel".into(), self.vel.clone())]
        }
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "vel" => super::restore_vec("vel", &mut self.vel, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for the QSGD master"),
            }
        }
        Ok(())
    }

    fn set_reduce_pool(&mut self, pool: ReducePool) {
        self.pool = pool;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{PNorm, PNormQuantizer};
    use std::sync::Arc;

    #[test]
    fn uplink_is_quantized_downlink_dense() {
        let x0 = vec![0.0; 8];
        let q = Arc::new(PNormQuantizer::new(PNorm::Inf, 4));
        let mut w = QsgdWorker::new(&x0, q);
        let mut m = QsgdMaster::new(&x0, 1, HyperParams::paper_defaults());
        let mut rng = Xoshiro256::seed_from_u64(1);
        let g = vec![1.0, -0.5, 0.25, 0.0, 2.0, 0.0, -1.0, 0.5];
        let up = w.round(0, &g, &mut rng);
        assert!(matches!(up, Compressed::Ternary { .. }));
        let down = m.round(0, &[Some(up)], &mut rng);
        assert!(matches!(down, Compressed::Dense(_)));
        w.apply_downlink(0, &down);
        assert_eq!(w.model(), m.model());
    }
}
