//! The seven distributed SGD algorithms, expressed as transport-independent
//! state machines (one [`WorkerNode`] per worker + one [`MasterNode`]).
//!
//! A synchronous round `k` is:
//! 1. every worker evaluates a stochastic gradient at its local model copy
//!    and [`WorkerNode::round`] turns it into an **uplink** payload;
//! 2. [`MasterNode::round`] consumes all uplinks and produces the
//!    **downlink** broadcast;
//! 3. every worker applies the downlink via [`WorkerNode::apply_downlink`].
//!
//! Every transport of the round engine ([`crate::engine`]) — in-process,
//! OS-thread channels, simulated network, TCP sockets — drives these same
//! state machines through the same loop, so convergence results and the
//! distributed runtime cannot drift apart.
//!
//! | algorithm | uplink | downlink | paper role |
//! |---|---|---|---|
//! | [`psgd`] | dense gradient | dense model | no-compression baseline |
//! | [`qsgd`] | `Q(g_i)` | dense model | Alistarh et al. 2017 |
//! | [`memsgd`] | `Q(g_i + e_i)` error-fed | dense model | Stich et al. 2018 |
//! | [`diana`] | `Q(g_i − h_i)` residual | dense model | Mishchenko et al. 2019 |
//! | [`doublesqueeze`] | `Q(g_i + e_i)` | `Q(avg + E)` | Tang et al. 2019 |
//! | [`dore`] | `Q(g_i − h_i)` residual | `Q(Δmodel + ηe)` residual | **this paper, Alg. 1/2** |

pub mod diana;
pub mod doublesqueeze;
pub mod dore;
pub mod memsgd;
pub mod psgd;
pub mod qsgd;

use crate::compression::{Compressed, Xoshiro256};
use crate::engine::reduce::ReducePool;
use crate::optim::{LrSchedule, Prox};
use crate::F;

/// Hyper-parameters shared by all algorithms. Fields an algorithm does not
/// use are ignored (e.g. `alpha` for P-SGD).
#[derive(Clone, Debug)]
pub struct HyperParams {
    /// Step size γ (overridden per round by `schedule` if set).
    pub lr: F,
    /// DORE/DIANA gradient-state step α.
    pub alpha: F,
    /// DORE model-residual step β.
    pub beta: F,
    /// DORE error-compensation weight η.
    pub eta: F,
    /// Master-side (heavy-ball) momentum on the recovered averaged
    /// gradient: `v ← m·v + ĝ; step with v`. 0 disables (the paper's
    /// setting); exposed as an extension since production PS frameworks
    /// train with momentum.
    pub momentum: F,
    /// Worker-side compressor spec (see [`crate::compression::from_spec`]).
    pub worker_compressor: String,
    /// Master-side compressor spec (downlink direction).
    pub master_compressor: String,
    /// Proximal regularizer `R` (DORE Algorithm 1; others apply it as a
    /// post-step prox too when set, which is the natural composite variant).
    pub prox: Prox,
    /// Optional LR schedule; `None` means constant `lr`.
    pub schedule: Option<LrSchedule>,
}

impl HyperParams {
    /// The paper's experimental settings (§5): α=0.1, β=1, η=1, Bernoulli
    /// ∞-norm quantization with block size 256 on both sides.
    pub fn paper_defaults() -> Self {
        Self {
            lr: 0.1,
            alpha: 0.1,
            beta: 1.0,
            eta: 1.0,
            momentum: 0.0,
            worker_compressor: "ternary:256".into(),
            master_compressor: "ternary:256".into(),
            prox: Prox::None,
            schedule: None,
        }
    }

    pub fn lr_at(&self, round: usize) -> F {
        self.schedule.as_ref().map_or(self.lr, |s| s.at(round))
    }

    /// Theory-recommended α for a worker compressor with constant `C_q`
    /// (Eq. 9): `α = 1 / (2(C_q + 1))`.
    pub fn theory_alpha(c_q: f64) -> F {
        (1.0 / (2.0 * (c_q + 1.0))) as F
    }

    /// Theory-recommended β for a master compressor with constant `C_qᵐ`
    /// (Eq. 9): `β = 1 / (C_qᵐ + 1)`.
    pub fn theory_beta(c_qm: f64) -> F {
        (1.0 / (c_qm + 1.0)) as F
    }
}

impl Default for HyperParams {
    fn default() -> Self {
        Self::paper_defaults()
    }
}

/// Worker-side state machine.
///
/// # Round-ordering and staleness contract
///
/// The engine guarantees, on every transport and at every pipeline depth:
///
/// * [`WorkerNode::round`] / [`WorkerNode::on_reused`] fire exactly once
///   per round, in strictly increasing round order;
/// * [`WorkerNode::apply_downlink`] also arrives in round order, but under
///   pipelined execution ([`crate::engine::TrainSpec::pipeline_depth`]
///   `= D ≥ 2`) it may **lag**: when `round(k)` is invoked, downlinks have
///   been applied only through round `k − D` — the local model is up to
///   `D − 1` rounds stale.
///
/// Because the per-round uplink folds (DORE/DIANA's
/// `h_i ← h_i + α·Δ̂_i`, the error-feedback `e_i` updates) depend only on
/// that round's payload — never on the downlink — the
/// `h = (1/n)Σ h_i` invariant survives the lag exactly; only the point the
/// gradient is evaluated at moves. [`WorkerNode::accept_staleness`] is the
/// explicit opt-in the engine collects before running with `D ≥ 2`.
pub trait WorkerNode: Send {
    /// Consume this round's local stochastic gradient, produce the uplink.
    fn round(&mut self, round: usize, grad: &[F], rng: &mut Xoshiro256) -> Compressed;

    /// Pipelined-execution staleness contract: before round 0 of a run with
    /// `pipeline_depth = D ≥ 2`, the engine announces `lag = D − 1` — the
    /// number of downlinks the local model may be missing when a gradient
    /// is evaluated (see the trait-level contract). Return an error to veto
    /// the run for algorithms whose analysis genuinely requires the
    /// synchronous model point. All seven built-in schemes tolerate any
    /// lag (their state folds are payload-driven), so the default accepts.
    fn accept_staleness(&mut self, _lag: usize) -> anyhow::Result<()> {
        Ok(())
    }

    /// Apply the master's downlink broadcast.
    fn apply_downlink(&mut self, round: usize, down: &Compressed);

    /// Notification that the transport replayed this worker's cached
    /// uplink `payload` for round `round` while the worker sat out
    /// ([`crate::engine::StalePolicy::ReuseLast`]). Algorithms whose
    /// master folds every received frame into shared state must mirror
    /// the fold here so the worker/master invariants survive partial
    /// participation (DORE/DIANA: `h_i ← h_i + α·payload`, keeping
    /// `h = (1/n)Σ h_i` exact). Error-feedback and stateless schemes need
    /// no correction — the default is a no-op.
    fn on_reused(&mut self, _round: usize, _payload: &Compressed) {}

    /// Recovery snapshot of the worker's algorithm-specific aux state
    /// (DORE/DIANA `h_i`, MEM-SGD/DoubleSqueeze `e_i`). The local model
    /// is **not** included: every built-in scheme keeps `x_i` bit-equal
    /// to the master's iterate after each applied downlink, so the
    /// checkpoint stores the model once. Stateless workers return
    /// nothing.
    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        Vec::new()
    }

    /// Restore this worker from a recovery snapshot: `model` replaces the
    /// local iterate, `aux` carries vectors a matching
    /// [`WorkerNode::export_state`] produced. A *missing* aux entry keeps
    /// the freshly-initialized value — that is exactly what a rejoining
    /// worker gets (empty aux: zeroed residual state, replayed model);
    /// an *unrecognized* name is an error so a mislabeled checkpoint
    /// fails loudly instead of restoring garbage. The default refuses:
    /// external algorithms opt in explicitly.
    fn import_state(&mut self, _model: &[F], _aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        anyhow::bail!("this worker does not support state restore (checkpoint resume / rejoin)")
    }

    /// Order-sensitive digest of the worker's residual / error-feedback
    /// state (DORE/DIANA `h_i`, MEM-SGD/DoubleSqueeze `e_i`). The
    /// participation invariance tests assert it is unchanged across a
    /// skipped round; stateless workers return 0.
    fn residual_digest(&self) -> u64 {
        0
    }

    /// The local model copy gradients are evaluated at (`x̂_i` for DORE).
    fn model(&self) -> &[F];

    /// ‖variable fed to the worker-side compressor‖ last round (Fig. 6).
    fn last_compressed_norm(&self) -> f64 {
        0.0
    }
}

/// Master-side state machine.
pub trait MasterNode: Send {
    /// Consume one round's gathered uplinks — one slot per worker, `None`
    /// for a worker that sat the round out under
    /// [`crate::engine::StalePolicy::Skip`] — and produce the downlink
    /// broadcast. Residual schemes treat an absent slot as `Δ̂_i = 0`
    /// (their `h` state already carries the absentee) and keep normalizing
    /// by `n`; gradient-averaging schemes normalize by the number of
    /// present slots instead.
    fn round(
        &mut self,
        round: usize,
        uplinks: &[Option<Compressed>],
        rng: &mut Xoshiro256,
    ) -> Compressed;

    /// The iterate to evaluate/report (`x̂ᵏ` for DORE, `xᵏ` otherwise).
    fn model(&self) -> &[F];

    /// Recovery snapshot of the master's aux state (DORE `h`, `e`;
    /// DoubleSqueeze `E`; heavy-ball velocity when momentum is on). The
    /// iterate itself is carried separately by the checkpoint.
    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        Vec::new()
    }

    /// Restore the master from a recovery snapshot (see
    /// [`WorkerNode::import_state`] for the missing-vs-unknown aux
    /// contract). The default refuses so external masters opt in.
    fn import_state(&mut self, _model: &[F], _aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        anyhow::bail!("this master does not support state restore (checkpoint resume)")
    }

    /// Install the dimension-sharded pool that drives this master's
    /// decode→average→compress sweeps ([`crate::engine::reduce`]). Called
    /// by the engine before round 0 with the pool configured on the
    /// [`crate::engine::TrainSpec`]; results must be bit-identical for
    /// every pool (the built-in masters shard by fixed dimension chunks,
    /// so they are). The default ignores the pool — external masters that
    /// never look at it simply stay serial.
    fn set_reduce_pool(&mut self, _pool: ReducePool) {}

    /// ‖variable fed to the master-side compressor‖ last round (Fig. 6).
    fn last_compressed_norm(&self) -> f64 {
        0.0
    }
}

/// Which algorithm to instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgorithmKind {
    /// Vanilla parallel SGD (no compression).
    Sgd,
    /// QSGD: quantized gradients, dense model broadcast.
    Qsgd,
    /// MEM-SGD: QSGD + worker-side error feedback.
    MemSgd,
    /// DIANA: gradient-difference compression, dense model broadcast.
    Diana,
    /// DoubleSqueeze: error-compensated compression both directions.
    DoubleSqueeze,
    /// DoubleSqueeze with biased top-k compression (Tang et al. 2019 §5).
    DoubleSqueezeTopk,
    /// DORE (this paper): double residual compression, Algorithm 1/2.
    Dore,
}

impl AlgorithmKind {
    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::Sgd => "SGD",
            AlgorithmKind::Qsgd => "QSGD",
            AlgorithmKind::MemSgd => "MEM-SGD",
            AlgorithmKind::Diana => "DIANA",
            AlgorithmKind::DoubleSqueeze => "DoubleSqueeze",
            AlgorithmKind::DoubleSqueezeTopk => "DoubleSqueeze(topk)",
            AlgorithmKind::Dore => "DORE",
        }
    }

    pub fn all() -> &'static [AlgorithmKind] {
        &[
            AlgorithmKind::Sgd,
            AlgorithmKind::Qsgd,
            AlgorithmKind::MemSgd,
            AlgorithmKind::Diana,
            AlgorithmKind::DoubleSqueeze,
            AlgorithmKind::DoubleSqueezeTopk,
            AlgorithmKind::Dore,
        ]
    }
}

impl std::str::FromStr for AlgorithmKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s.to_lowercase().as_str() {
            "sgd" | "psgd" => AlgorithmKind::Sgd,
            "qsgd" => AlgorithmKind::Qsgd,
            "mem-sgd" | "memsgd" => AlgorithmKind::MemSgd,
            "diana" => AlgorithmKind::Diana,
            "double-squeeze" | "doublesqueeze" => AlgorithmKind::DoubleSqueeze,
            "double-squeeze-topk" | "doublesqueeze-topk" | "doublesqueeze(topk)" => {
                AlgorithmKind::DoubleSqueezeTopk
            }
            "dore" => AlgorithmKind::Dore,
            other => anyhow::bail!(
                "unknown algorithm '{other}' \
                 (sgd|qsgd|mem-sgd|diana|double-squeeze|double-squeeze-topk|dore)"
            ),
        })
    }
}

/// Instantiate the worker fleet + master for `kind`, all starting from the
/// identical iterate `x0` (§3.2 Initialization). Construction is
/// registry-based ([`crate::engine::registry`]): each algorithm's entry owns
/// its compressor policy, and new schemes register without editing this
/// module.
pub fn build(
    kind: AlgorithmKind,
    n_workers: usize,
    x0: &[F],
    hp: &HyperParams,
) -> anyhow::Result<(Vec<Box<dyn WorkerNode>>, Box<dyn MasterNode>)> {
    crate::engine::registry::build_algorithm(kind, n_workers, x0, hp)
}

/// Heavy-ball momentum update: `vel ← m·vel + g` (vel lazily sized).
pub(crate) fn apply_momentum(m: F, g: &[F], vel: &mut Vec<F>) {
    if m <= 0.0 {
        return;
    }
    if vel.is_empty() {
        vel.resize(g.len(), 0.0);
    }
    for (v, &gi) in vel.iter_mut().zip(g.iter()) {
        *v = m * *v + gi;
    }
}

/// The dense-broadcast step tail `x ← prox_{γ R}(x + step_scale·step)`,
/// with the heavy-ball fold `vel ← m·vel + g` fused in when momentum is
/// on — parallelized across `pool`'s dimension shards instead of running
/// serially after the reduce (§Perf). Bit-identical to the serial
/// `apply_momentum` + `linalg::axpy` + `Prox::apply` sequence: every
/// coordinate evaluates the same expression tree, shards are disjoint,
/// and the prox is separable ([`Prox::apply_one`] agrees with
/// [`Prox::apply`] coordinate-wise).
pub(crate) fn dense_step_tail(
    pool: &ReducePool,
    step_scale: F,
    prox_gamma: F,
    momentum: F,
    prox: Prox,
    g: &[F],
    vel: &mut Vec<F>,
    x: &mut [F],
) {
    if momentum > 0.0 {
        if vel.is_empty() {
            vel.resize(g.len(), 0.0);
        }
        pool.sweep2(x, vel, |lo, xc, vc| {
            for (j, (xv, vv)) in xc.iter_mut().zip(vc.iter_mut()).enumerate() {
                *vv = momentum * *vv + g[lo + j];
                *xv = prox.apply_one(prox_gamma, *xv + step_scale * *vv);
            }
        });
    } else {
        pool.sweep1(x, |lo, xc| {
            for (j, xv) in xc.iter_mut().enumerate() {
                *xv = prox.apply_one(prox_gamma, *xv + step_scale * g[lo + j]);
            }
        });
    }
}

/// Average the *present* uplinks into a dense buffer:
/// `out = (1/|S|) Σ_{i∈S} decode(m_i)` where `S` is the set of `Some`
/// slots. An empty round leaves `out` zero (the step is a no-op). The sum
/// is swept over `pool`'s dimension shards, each payload decoding straight
/// into the destination shard; per coordinate the slots fold in order, so
/// the result is bit-identical for every thread count.
pub(crate) fn average_present(uplinks: &[Option<Compressed>], out: &mut [F], pool: &ReducePool) {
    out.fill(0.0);
    let present = uplinks.iter().flatten().count();
    if present == 0 {
        return;
    }
    let inv = 1.0 / present as F;
    pool.accumulate(uplinks, inv, out);
}

/// Copy a checkpointed vector over live state, rejecting dimension
/// mismatches with the vector's name in the message — shared by the
/// `import_state` impls. A lazily-allocated destination (the heavy-ball
/// velocity before its first use) is sized from the source.
pub(crate) fn restore_vec(name: &str, dst: &mut Vec<F>, src: &[F]) -> anyhow::Result<()> {
    if dst.is_empty() && !src.is_empty() {
        *dst = src.to_vec();
        return Ok(());
    }
    anyhow::ensure!(
        dst.len() == src.len(),
        "checkpoint vector '{name}' has dimension {} but this run needs {}",
        src.len(),
        dst.len()
    );
    dst.copy_from_slice(src);
    Ok(())
}

/// FNV-1a over the f32 bit patterns — the cheap order-sensitive digest
/// behind [`WorkerNode::residual_digest`].
pub fn digest_f32(xs: &[F]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_every_kind() {
        let x0 = vec![0.0; 32];
        for &k in AlgorithmKind::all() {
            let (ws, m) = build(k, 3, &x0, &HyperParams::paper_defaults()).unwrap();
            assert_eq!(ws.len(), 3);
            assert_eq!(m.model().len(), 32);
            for w in &ws {
                assert_eq!(w.model(), &x0[..]);
            }
        }
    }

    #[test]
    fn theory_constants() {
        assert!((HyperParams::theory_alpha(0.0) - 0.5).abs() < 1e-7);
        assert!((HyperParams::theory_beta(0.0) - 1.0).abs() < 1e-7);
        assert!((HyperParams::theory_alpha(1.0) - 0.25).abs() < 1e-7);
    }
}
