//! **DORE** — the paper's contribution (Algorithm 1; Algorithm 2 is the
//! special case `R = 0`, which this implementation recovers automatically
//! since `prox_{γ·0}` is the identity).
//!
//! Uplink (worker `i`, lines 4–9):
//! ```text
//! Δ_i = g_i − h_i            gradient residual
//! send Δ̂_i = Q(Δ_i)
//! h_i ← h_i + α·Δ̂_i          (E_Q h_i^{k+1} = (1−α)h_i + α g_i — Lemma 1)
//! ```
//!
//! Downlink (master, lines 13–22):
//! ```text
//! ĝ = h + (1/n)Σ Δ̂_i         recovered averaged gradient
//! h ← h + α·(1/n)Σ Δ̂_i
//! x^{k+1} = prox_{γR}(x̂ − γ·ĝ)
//! q = x^{k+1} − x̂ + η·e      model residual, error-compensated
//! broadcast q̂ = Q_m(q);  e ← q − q̂;  x̂ ← x̂ + β·q̂
//! ```
//!
//! Every worker applies `x̂_i ← x̂_i + β·q̂` (lines 10–11), so all copies of
//! `x̂` remain bit-identical given the identical initialization (§3.2).
//! Both residuals vanish as the iterates converge, so the compression
//! variance vanishes too — the mechanism behind the linear convergence of
//! Theorem 1 and the exponential residual decay of Fig. 6.

use super::{digest_f32, HyperParams, MasterNode, WorkerNode};
use crate::compression::{BoxedCompressor, Compressed, Xoshiro256};
use crate::engine::reduce::ReducePool;
use crate::models::linalg;
use crate::F;

pub struct DoreWorker {
    /// Local reference model x̂_i (gradients are evaluated here).
    x: Vec<F>,
    /// Gradient state h_i.
    h: Vec<F>,
    delta: Vec<F>,
    q: BoxedCompressor,
    hp: HyperParams,
    last_norm: f64,
}

impl DoreWorker {
    pub fn new(x0: &[F], q: BoxedCompressor, hp: HyperParams) -> Self {
        Self {
            x: x0.to_vec(),
            h: vec![0.0; x0.len()],
            delta: vec![0.0; x0.len()],
            q,
            hp,
            last_norm: 0.0,
        }
    }

    #[cfg(test)]
    pub(crate) fn h(&self) -> &[F] {
        &self.h
    }
}

impl WorkerNode for DoreWorker {
    fn round(&mut self, _round: usize, grad: &[F], rng: &mut Xoshiro256) -> Compressed {
        // Δ_i = g_i − h_i  (line 5)
        for (d, (&g, &h)) in self.delta.iter_mut().zip(grad.iter().zip(self.h.iter())) {
            *d = g - h;
        }
        self.last_norm = linalg::norm2(&self.delta);
        let up = self.q.compress(&self.delta, rng); // line 6
        up.add_scaled_into(self.hp.alpha, &mut self.h); // line 7
        up
    }

    fn apply_downlink(&mut self, _round: usize, down: &Compressed) {
        // x̂_i ← x̂_i + β·q̂  (line 11)
        down.add_scaled_into(self.hp.beta, &mut self.x);
    }

    fn on_reused(&mut self, _round: usize, payload: &Compressed) {
        // the master folded the replayed Δ̂ into its h (line 17's update
        // is indistinguishable from a fresh frame); mirror line 7 so
        // h = (1/n)Σ h_i stays exact
        payload.add_scaled_into(self.hp.alpha, &mut self.h);
    }

    fn residual_digest(&self) -> u64 {
        digest_f32(&self.h)
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        vec![("h".into(), self.h.clone())]
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "h" => super::restore_vec("h", &mut self.h, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for a DORE worker"),
            }
        }
        Ok(())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn last_compressed_norm(&self) -> f64 {
        self.last_norm
    }
}

pub struct DoreMaster {
    /// Reference model x̂ (identical to every worker's copy).
    xhat: Vec<F>,
    /// Averaged gradient state h = (1/n)Σ h_i.
    h: Vec<F>,
    /// Model-residual compression error e.
    e: Vec<F>,
    ghat: Vec<F>,
    xnext: Vec<F>,
    qbuf: Vec<F>,
    vel: Vec<F>,
    n: usize,
    mq: BoxedCompressor,
    hp: HyperParams,
    last_norm: f64,
    pool: ReducePool,
}

impl DoreMaster {
    pub fn new(x0: &[F], n: usize, mq: BoxedCompressor, hp: HyperParams) -> Self {
        let d = x0.len();
        Self {
            xhat: x0.to_vec(),
            h: vec![0.0; d],
            e: vec![0.0; d],
            ghat: vec![0.0; d],
            xnext: vec![0.0; d],
            qbuf: vec![0.0; d],
            vel: Vec::new(),
            n,
            mq,
            hp,
            last_norm: 0.0,
            pool: ReducePool::serial(),
        }
    }

    #[cfg(test)]
    pub(crate) fn h(&self) -> &[F] {
        &self.h
    }
}

impl MasterNode for DoreMaster {
    fn round(
        &mut self,
        round: usize,
        uplinks: &[Option<Compressed>],
        rng: &mut Xoshiro256,
    ) -> Compressed {
        debug_assert_eq!(uplinks.len(), self.n);
        let inv = 1.0 / self.n as F;
        let alpha_inv = self.hp.alpha * inv;
        let pool = self.pool.clone();
        // ĝ = h + (1/n)Σ_{i∈S} Δ̂_i; h ← h + α·(1/n)Σ_{i∈S} Δ̂_i (lines
        // 14–15, 17) — one fused decode pass per uplink instead of two,
        // swept over the pool's dimension shards (§Perf). An absent slot
        // is Δ̂_i = 0: the worker that sat out left its h_i alone, its
        // stale gradient estimate is already inside h, and the
        // normalization stays 1/n — this is how DORE's gradient state
        // absorbs partial participation natively. Within a shard the
        // uplinks fold in slot order, so every coordinate sees the serial
        // accumulation order for any reduce-thread count;
        // `add_scaled2_range_into` keeps the per-coordinate expression
        // tree (`v` decoded once, two scaled adds) while running the
        // fixed-width vector kernels.
        {
            let (ghat, h) = (&mut self.ghat, &mut self.h);
            pool.sweep2(ghat, h, |lo, gc, hc| {
                gc.copy_from_slice(hc);
                for m in uplinks.iter().flatten() {
                    m.add_scaled2_range_into(lo, inv, gc, alpha_inv, hc);
                }
            });
        }
        // x^{k+1} = prox_{γR}(x̂ − γĝ) and q = x^{k+1} − x̂ + η·e
        // (lines 16, 18) fused into one sharded sweep — prox is separable,
        // so shards are independent; ‖q‖ is folded from fixed per-shard
        // partials (deterministic for any thread count).
        let gamma = self.hp.lr_at(round);
        if self.hp.momentum > 0.0 {
            // extension: heavy-ball on the recovered gradient estimate.
            super::apply_momentum(self.hp.momentum, &self.ghat, &mut self.vel);
            self.ghat.copy_from_slice(&self.vel);
        }
        let prox = self.hp.prox;
        let eta = self.hp.eta;
        let shard = pool.shard_width();
        let d = self.qbuf.len();
        let mut qsq = vec![0.0f64; d.div_ceil(shard)];
        // §Perf: when the downlink compressor's norm is fusable (∞-norm —
        // order-independent max) and its block grid aligns with the shard
        // grid, the per-block norms are computed *inside* this sweep from
        // the freshly written q values, so `compress_with_norms` skips an
        // entire extra read of q. The maxima are bitwise the serial
        // `block_norm`'s, so payload + RNG stream stay identical.
        let fused_bs = self.mq.fused_norm_block().filter(|&bs| shard % bs == 0);
        let mut fused_norms = fused_bs.map(|bs| vec![0.0f32; d.div_ceil(bs)]);
        {
            let (qbuf, xnext) = (&mut self.qbuf, &mut self.xnext);
            let (xhat, ghat, e) = (&self.xhat, &self.ghat, &self.e);
            let fill_q = |lo: usize, qc: &mut [F], xc: &mut [F]| -> f64 {
                let mut acc = 0.0f64;
                for (j, (q, xn)) in qc.iter_mut().zip(xc.iter_mut()).enumerate() {
                    let i = lo + j;
                    let x_new = prox.apply_one(gamma, xhat[i] - gamma * ghat[i]);
                    *xn = x_new;
                    let qv = x_new - xhat[i] + eta * e[i];
                    *q = qv;
                    acc += (qv as f64) * (qv as f64);
                }
                acc
            };
            match (&mut fused_norms, fused_bs) {
                (Some(norms), Some(bs)) => {
                    let blocks_per_shard = shard / bs;
                    let items: Vec<(usize, &mut [F], &mut [F], &mut f64, &mut [F])> = qbuf
                        .chunks_mut(shard)
                        .zip(xnext.chunks_mut(shard))
                        .zip(qsq.iter_mut())
                        .zip(norms.chunks_mut(blocks_per_shard))
                        .enumerate()
                        .map(|(c, (((qc, xc), sq), nc))| (c * shard, qc, xc, sq, nc))
                        .collect();
                    pool.run(items, |(lo, qc, xc, sq, nc)| {
                        *sq = fill_q(lo, qc, xc);
                        for (block, nv) in qc.chunks(bs).zip(nc.iter_mut()) {
                            *nv = crate::compression::kernel::max_abs(block);
                        }
                    });
                }
                _ => {
                    let items: Vec<(usize, &mut [F], &mut [F], &mut f64)> = qbuf
                        .chunks_mut(shard)
                        .zip(xnext.chunks_mut(shard))
                        .zip(qsq.iter_mut())
                        .enumerate()
                        .map(|(c, ((qc, xc), sq))| (c * shard, qc, xc, sq))
                        .collect();
                    pool.run(items, |(lo, qc, xc, sq)| {
                        *sq = fill_q(lo, qc, xc);
                    });
                }
            }
        }
        // lint:allow(float_fold, folds shard partials in slot order; shard count is thread-independent)
        self.last_norm = qsq.iter().sum::<f64>().sqrt();
        // line 19 — the model-residual downlink, compressed over the same
        // shards (identical payload + RNG stream as the serial compress),
        // reusing the fused norms when the sweep produced them.
        let down = match fused_norms {
            Some(norms) => self.mq.compress_with_norms(&self.qbuf, norms, rng, &pool),
            None => self.mq.compress_sharded(&self.qbuf, rng, &pool),
        };
        // e ← q − q̂; x̂ ← x̂ + β·q̂  (lines 20–21) — one fused decode
        // sweep over the shards, running the fixed-width residual kernel.
        {
            let (e, xhat) = (&mut self.e, &mut self.xhat);
            let qbuf = &self.qbuf;
            let beta = self.hp.beta;
            let down_ref = &down;
            pool.sweep2(e, xhat, |lo, ec, xc| {
                down_ref.fold_residual_range(lo, &qbuf[lo..lo + ec.len()], beta, ec, xc);
            });
        }
        down
    }

    fn model(&self) -> &[F] {
        &self.xhat
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        let mut aux = vec![("h".into(), self.h.clone()), ("e".into(), self.e.clone())];
        if !self.vel.is_empty() {
            aux.push(("vel".into(), self.vel.clone()));
        }
        aux
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x̂", &mut self.xhat, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "h" => super::restore_vec("h", &mut self.h, v)?,
                "e" => super::restore_vec("e", &mut self.e, v)?,
                "vel" => super::restore_vec("vel", &mut self.vel, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for the DORE master"),
            }
        }
        Ok(())
    }

    fn set_reduce_pool(&mut self, pool: ReducePool) {
        self.pool = pool;
    }

    fn last_compressed_norm(&self) -> f64 {
        self.last_norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{from_spec, Identity};
    use std::sync::Arc;

    fn hp(lr: F) -> HyperParams {
        HyperParams { lr, ..HyperParams::paper_defaults() }
    }

    #[test]
    fn no_compression_beta1_eta0_is_gradient_descent() {
        // With identity compressors, β=1, η=0: x̂^{k+1} = x̂ − γ·g exactly.
        let x0 = vec![1.0, -2.0];
        let mut hp = hp(0.5);
        hp.beta = 1.0;
        hp.eta = 0.0;
        hp.alpha = 1.0;
        let mut w = DoreWorker::new(&x0, Arc::new(Identity), hp.clone());
        let mut m = DoreMaster::new(&x0, 1, Arc::new(Identity), hp);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let g = vec![2.0, 2.0];
        let up = w.round(0, &g, &mut rng);
        let down = m.round(0, &[Some(up)], &mut rng);
        w.apply_downlink(0, &down);
        assert_eq!(m.model(), &[0.0, -3.0]);
        assert_eq!(w.model(), m.model());
    }

    #[test]
    fn worker_and_master_models_stay_bit_identical() {
        // The central §3.2 invariant: x̂_i == x̂ after every round, without
        // any model broadcast — both sides apply the same β·q̂.
        let x0: Vec<F> = (0..32).map(|i| (i as F * 0.1).sin()).collect();
        let h = hp(0.05);
        let wq = from_spec("ternary:8").unwrap();
        let mq = from_spec("ternary:8").unwrap();
        let mut workers: Vec<DoreWorker> =
            (0..3).map(|_| DoreWorker::new(&x0, wq.clone(), h.clone())).collect();
        let mut master = DoreMaster::new(&x0, 3, mq, h);
        for k in 0..20u64 {
            let ups: Vec<Option<Compressed>> = workers
                .iter_mut()
                .enumerate()
                .map(|(i, w)| {
                    let g: Vec<F> = (0..32).map(|j| ((i + j) as F + k as F * 0.3).cos()).collect();
                    let mut rng = Xoshiro256::for_site(3, 1 + i as u64, k);
                    Some(w.round(k as usize, &g, &mut rng))
                })
                .collect();
            let mut mrng = Xoshiro256::for_site(3, 0, k);
            let down = master.round(k as usize, &ups, &mut mrng);
            for w in workers.iter_mut() {
                w.apply_downlink(k as usize, &down);
            }
            for w in &workers {
                assert_eq!(w.model(), master.model(), "x̂ desync at round {k}");
            }
        }
    }

    #[test]
    fn master_h_equals_average_of_worker_h() {
        let x0 = vec![0.0; 16];
        let h = hp(0.1);
        let wq = from_spec("ternary:4").unwrap();
        let mq = from_spec("ternary:4").unwrap();
        let mut workers: Vec<DoreWorker> =
            (0..2).map(|_| DoreWorker::new(&x0, wq.clone(), h.clone())).collect();
        let mut master = DoreMaster::new(&x0, 2, mq, h);
        for k in 0..8u64 {
            let ups: Vec<Option<Compressed>> = workers
                .iter_mut()
                .enumerate()
                .map(|(i, w)| {
                    let g: Vec<F> =
                        (0..16).map(|j| (i as F + 1.0) * ((j as F) - 8.0) * 0.1).collect();
                    let mut rng = Xoshiro256::for_site(8, 1 + i as u64, k);
                    Some(w.round(k as usize, &g, &mut rng))
                })
                .collect();
            let mut mrng = Xoshiro256::for_site(8, 0, k);
            let down = master.round(k as usize, &ups, &mut mrng);
            for w in workers.iter_mut() {
                w.apply_downlink(k as usize, &down);
            }
        }
        for j in 0..16 {
            let avg = (workers[0].h()[j] + workers[1].h()[j]) / 2.0;
            assert!((master.h()[j] - avg).abs() < 1e-5, "h desync at coord {j}");
        }
    }

    #[test]
    fn error_compensation_state_is_consistent() {
        // e^{k+1} = q^k − q̂^k: reconstruct q from e + decoded broadcast.
        let x0 = vec![0.5; 12];
        let mut h = hp(0.2);
        h.eta = 1.0;
        let wq = from_spec("ternary:4").unwrap();
        let mq = from_spec("ternary:4").unwrap();
        let mut w = DoreWorker::new(&x0, wq, h.clone());
        let mut m = DoreMaster::new(&x0, 1, mq, h);
        let mut rng = Xoshiro256::seed_from_u64(44);
        let g = vec![1.0; 12];
        let up = w.round(0, &g, &mut rng);
        let down = m.round(0, &[Some(up)], &mut rng);
        let mut q_rec = m.e.clone();
        down.add_scaled_into(1.0, &mut q_rec);
        for (qr, qb) in q_rec.iter().zip(&m.qbuf) {
            assert!((qr - qb).abs() < 1e-6);
        }
    }

    #[test]
    fn partial_participation_preserves_h_and_model_invariants() {
        // the §3.2 invariants under k-of-n rounds: x̂_i == x̂ always (the
        // broadcast reaches everyone), and h == (1/n)Σ h_i under both the
        // skip (absent slot) and reuse-last (replayed slot + on_reused)
        // policies.
        let x0: Vec<F> = (0..24).map(|i| (i as F * 0.2).cos()).collect();
        let h = hp(0.05);
        let wq = from_spec("ternary:8").unwrap();
        let mq = from_spec("ternary:8").unwrap();
        let mut workers: Vec<DoreWorker> =
            (0..3).map(|_| DoreWorker::new(&x0, wq.clone(), h.clone())).collect();
        let mut master = DoreMaster::new(&x0, 3, mq, h);
        let mut last: Vec<Option<Compressed>> = vec![None; 3];
        for k in 0..24usize {
            // rotate one absentee per round; alternate skip/reuse rounds
            let absent = k % 3;
            let reuse = k % 2 == 1;
            let mut skipped_digest: Option<u64> = None;
            let mut slots: Vec<Option<Compressed>> = Vec::new();
            for (i, w) in workers.iter_mut().enumerate() {
                if i != absent {
                    let g: Vec<F> =
                        (0..24).map(|j| ((i + j) as F + k as F * 0.7).sin()).collect();
                    let mut rng = Xoshiro256::for_site(11, 1 + i as u64, k as u64);
                    let up = w.round(k, &g, &mut rng);
                    last[i] = Some(up.clone());
                    slots.push(Some(up));
                } else if reuse && last[i].is_some() {
                    let stale = last[i].clone().unwrap();
                    w.on_reused(k, &stale);
                    slots.push(Some(stale));
                } else {
                    skipped_digest = Some(w.residual_digest());
                    slots.push(None);
                }
            }
            let mut mrng = Xoshiro256::for_site(11, 0, k as u64);
            let down = master.round(k, &slots, &mut mrng);
            for w in workers.iter_mut() {
                w.apply_downlink(k, &down);
            }
            if let Some(before) = skipped_digest {
                // the full round — master step included — must not have
                // moved the skipped worker's h (the downlink touches x̂ only)
                assert_eq!(
                    workers[absent].residual_digest(),
                    before,
                    "skip moved worker {absent}'s h at round {k}"
                );
            }
            for w in &workers {
                assert_eq!(w.model(), master.model(), "x̂ desync at round {k}");
            }
            for j in 0..24 {
                let avg: F = workers.iter().map(|w| w.h()[j]).sum::<F>() / 3.0;
                assert!(
                    (master.h()[j] - avg).abs() < 1e-5,
                    "h desync at round {k} coord {j}: {} vs {avg}",
                    master.h()[j]
                );
            }
        }
    }

    #[test]
    fn prox_l1_produces_sparse_iterates() {
        use crate::optim::Prox;
        let x0 = vec![0.0; 8];
        let mut h = hp(0.5);
        h.prox = Prox::L1 { lambda: 0.4 };
        let mut w = DoreWorker::new(&x0, Arc::new(Identity), h.clone());
        let mut m = DoreMaster::new(&x0, 1, Arc::new(Identity), h);
        let mut rng = Xoshiro256::seed_from_u64(0);
        // gradient pushing only coords 0/1 strongly; prox should zero the rest
        let g = vec![-4.0, -3.0, -0.2, 0.1, -0.3, 0.2, -0.1, 0.05];
        let up = w.round(0, &g, &mut rng);
        let down = m.round(0, &[Some(up)], &mut rng);
        w.apply_downlink(0, &down);
        let x = m.model();
        assert!(x[0] > 0.0 && x[1] > 0.0);
        assert!(x[2..].iter().all(|&v| v == 0.0), "{x:?}");
    }
}
