//! Vanilla parallel SGD (no compression): workers upload dense gradients,
//! the master steps and broadcasts the dense model. The paper's
//! full-precision baseline ("SGD" in all figures).

use super::{average_present, HyperParams, MasterNode, WorkerNode};
use crate::compression::{BoxedCompressor, Compressed, Xoshiro256};
use crate::engine::reduce::ReducePool;
use crate::models::linalg;
use crate::F;

pub struct PsgdWorker {
    x: Vec<F>,
    q: BoxedCompressor,
    last_norm: f64,
}

impl PsgdWorker {
    pub fn new(x0: &[F], q: BoxedCompressor) -> Self {
        Self { x: x0.to_vec(), q, last_norm: 0.0 }
    }
}

impl WorkerNode for PsgdWorker {
    fn round(&mut self, _round: usize, grad: &[F], rng: &mut Xoshiro256) -> Compressed {
        self.last_norm = linalg::norm2(grad);
        self.q.compress(grad, rng)
    }

    fn apply_downlink(&mut self, _round: usize, down: &Compressed) {
        // dense model replacement
        self.x.fill(0.0);
        down.add_scaled_into(1.0, &mut self.x);
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        if let Some((name, _)) = aux.first() {
            anyhow::bail!("unknown aux vector '{name}' for an SGD worker (it keeps none)");
        }
        Ok(())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn last_compressed_norm(&self) -> f64 {
        self.last_norm
    }
}

pub struct PsgdMaster {
    x: Vec<F>,
    gbar: Vec<F>,
    /// heavy-ball velocity (allocated lazily when momentum > 0)
    vel: Vec<F>,
    n: usize,
    hp: HyperParams,
    pool: ReducePool,
}

impl PsgdMaster {
    pub fn new(x0: &[F], n: usize, hp: HyperParams) -> Self {
        Self {
            x: x0.to_vec(),
            gbar: vec![0.0; x0.len()],
            vel: Vec::new(),
            n,
            hp,
            pool: ReducePool::serial(),
        }
    }
}

impl MasterNode for PsgdMaster {
    fn round(
        &mut self,
        round: usize,
        uplinks: &[Option<Compressed>],
        _rng: &mut Xoshiro256,
    ) -> Compressed {
        debug_assert_eq!(uplinks.len(), self.n);
        // partial participation: average over whoever showed up
        average_present(uplinks, &mut self.gbar, &self.pool);
        let gamma = self.hp.lr_at(round);
        // x ← prox_{γR}(x − γ·step), momentum fold included, swept over
        // the pool's dimension shards (§Perf).
        super::dense_step_tail(
            &self.pool,
            -gamma,
            gamma,
            self.hp.momentum,
            self.hp.prox,
            &self.gbar,
            &mut self.vel,
            &mut self.x,
        );
        Compressed::Dense(self.x.clone())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        if self.vel.is_empty() {
            Vec::new()
        } else {
            vec![("vel".into(), self.vel.clone())]
        }
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "vel" => super::restore_vec("vel", &mut self.vel, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for the SGD master"),
            }
        }
        Ok(())
    }

    fn set_reduce_pool(&mut self, pool: ReducePool) {
        self.pool = pool;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::Identity;
    use std::sync::Arc;

    #[test]
    fn one_round_is_plain_gd_step() {
        let x0 = vec![1.0, 2.0];
        let hp = HyperParams { lr: 0.5, ..HyperParams::paper_defaults() };
        let mut w = PsgdWorker::new(&x0, Arc::new(Identity));
        let mut m = PsgdMaster::new(&x0, 1, hp);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let up = w.round(0, &[2.0, -2.0], &mut rng);
        let down = m.round(0, &[Some(up)], &mut rng);
        w.apply_downlink(0, &down);
        assert_eq!(m.model(), &[0.0, 3.0]);
        assert_eq!(w.model(), m.model());
    }

    #[test]
    fn master_averages_across_workers() {
        let x0 = vec![0.0];
        let hp = HyperParams { lr: 1.0, ..HyperParams::paper_defaults() };
        let mut m = PsgdMaster::new(&x0, 2, hp);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let ups = vec![Some(Compressed::Dense(vec![2.0])), Some(Compressed::Dense(vec![4.0]))];
        m.round(0, &ups, &mut rng);
        assert_eq!(m.model(), &[-3.0]); // x - 1.0 * mean(2,4)
    }

    #[test]
    fn master_averages_over_participants_only() {
        let x0 = vec![0.0];
        let hp = HyperParams { lr: 1.0, ..HyperParams::paper_defaults() };
        let mut m = PsgdMaster::new(&x0, 2, hp);
        let mut rng = Xoshiro256::seed_from_u64(0);
        // worker 0 sat out: the step uses worker 1's gradient alone
        m.round(0, &[None, Some(Compressed::Dense(vec![4.0]))], &mut rng);
        assert_eq!(m.model(), &[-4.0]);
        // an empty round is a no-op step, not a NaN
        let mut m2 =
            PsgdMaster::new(&x0, 2, HyperParams { lr: 1.0, ..HyperParams::paper_defaults() });
        m2.round(0, &[None, None], &mut rng);
        assert_eq!(m2.model(), &[0.0]);
    }
}
