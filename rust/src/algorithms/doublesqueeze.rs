//! DoubleSqueeze (Tang et al., 2019): error-compensated compression on
//! **both** sides, but of the raw (γ-scaled) gradients rather than
//! residuals.
//!
//! Worker: `p_i = γ·g_i + e_i; send Q(p_i); e_i = p_i − Q(p_i)`.
//! Master: `v = mean(Q(p_i)) + E; broadcast u = Q(v); E = v − u`;
//! every node applies `x ← x − u`.
//!
//! Because the compressed quantity does **not** vanish at the optimum
//! (its norm ≈ γ‖g‖ + accumulated error), the compression error never
//! dies out: with unbiased ternary quantization DoubleSqueeze plateaus
//! (and diverges at lr 0.05 in Fig. 3); with biased top-k it behaves much
//! better — both regimes are reproduced by choosing the compressor.

use super::{digest_f32, HyperParams, MasterNode, WorkerNode};
use crate::compression::{BoxedCompressor, Compressed, Xoshiro256};
use crate::engine::reduce::ReducePool;
use crate::models::linalg;
use crate::F;

pub struct DsWorker {
    x: Vec<F>,
    e: Vec<F>,
    buf: Vec<F>,
    q: BoxedCompressor,
    hp: HyperParams,
    last_norm: f64,
}

impl DsWorker {
    pub fn new(x0: &[F], q: BoxedCompressor, hp: HyperParams) -> Self {
        Self {
            x: x0.to_vec(),
            e: vec![0.0; x0.len()],
            buf: vec![0.0; x0.len()],
            q,
            hp,
            last_norm: 0.0,
        }
    }
}

impl WorkerNode for DsWorker {
    fn round(&mut self, round: usize, grad: &[F], rng: &mut Xoshiro256) -> Compressed {
        let gamma = self.hp.lr_at(round);
        // p = γ·g + e
        self.buf.copy_from_slice(&self.e);
        linalg::axpy(gamma, grad, &mut self.buf);
        self.last_norm = linalg::norm2(&self.buf);
        let up = self.q.compress(&self.buf, rng);
        self.e.copy_from_slice(&self.buf);
        up.add_scaled_into(-1.0, &mut self.e);
        up
    }

    fn apply_downlink(&mut self, _round: usize, down: &Compressed) {
        // x ← x − u (the step size is already inside u)
        down.add_scaled_into(-1.0, &mut self.x);
    }

    // a replayed frame was already error-compensated when first sent; the
    // worker's e_i needs no correction, so the default no-op `on_reused`
    // is the right semantics.

    fn residual_digest(&self) -> u64 {
        digest_f32(&self.e)
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        vec![("e".into(), self.e.clone())]
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "e" => super::restore_vec("e", &mut self.e, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for a DoubleSqueeze worker"),
            }
        }
        Ok(())
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn last_compressed_norm(&self) -> f64 {
        self.last_norm
    }
}

pub struct DsMaster {
    x: Vec<F>,
    /// master-side error accumulator E
    err: Vec<F>,
    v: Vec<F>,
    n: usize,
    mq: BoxedCompressor,
    hp: HyperParams,
    last_norm: f64,
    pool: ReducePool,
}

impl DsMaster {
    pub fn new(x0: &[F], n: usize, mq: BoxedCompressor, hp: HyperParams) -> Self {
        Self {
            x: x0.to_vec(),
            err: vec![0.0; x0.len()],
            v: vec![0.0; x0.len()],
            n,
            mq,
            hp,
            last_norm: 0.0,
            pool: ReducePool::serial(),
        }
    }
}

impl MasterNode for DsMaster {
    fn round(
        &mut self,
        round: usize,
        uplinks: &[Option<Compressed>],
        rng: &mut Xoshiro256,
    ) -> Compressed {
        debug_assert_eq!(uplinks.len(), self.n);
        // v = mean over participants of Q(p_i), plus E — the γ lives
        // inside the uplinks, so averaging over |S| keeps the step size
        // right under partial participation. Decoded shard-by-shard
        // straight into v (slot order within each shard = the serial
        // accumulation order), with ‖v‖ folded from fixed per-shard
        // partials.
        let present = uplinks.iter().flatten().count();
        let inv = 1.0 / present.max(1) as F;
        let pool = self.pool.clone();
        let shard = pool.shard_width();
        let mut vsq = vec![0.0f64; self.v.len().div_ceil(shard)];
        // §Perf: with a fusable (∞-norm) downlink compressor whose block
        // grid divides the shard grid, the per-block norms fall out of
        // this same sweep (order-independent max ⇒ bitwise the serial
        // block_norm), and `compress_with_norms` skips re-reading v.
        let fused_bs = self.mq.fused_norm_block().filter(|&bs| shard % bs == 0);
        let mut fused_norms = fused_bs.map(|bs| vec![0.0f32; self.v.len().div_ceil(bs)]);
        {
            let err = &self.err;
            let fill_v = |lo: usize, vc: &mut [F]| -> f64 {
                vc.copy_from_slice(&err[lo..lo + vc.len()]);
                for m in uplinks.iter().flatten() {
                    m.add_scaled_range_into(inv, lo, vc);
                }
                // lint:allow(float_fold, per-shard partial inside the ReducePool fixed-shard fold)
                vc.iter().map(|&x| (x as f64) * (x as f64)).sum()
            };
            match (&mut fused_norms, fused_bs) {
                (Some(norms), Some(bs)) => {
                    let blocks_per_shard = shard / bs;
                    let items: Vec<(usize, &mut [F], &mut f64, &mut [F])> = self
                        .v
                        .chunks_mut(shard)
                        .zip(vsq.iter_mut())
                        .zip(norms.chunks_mut(blocks_per_shard))
                        .enumerate()
                        .map(|(c, ((vc, sq), nc))| (c * shard, vc, sq, nc))
                        .collect();
                    pool.run(items, |(lo, vc, sq, nc)| {
                        *sq = fill_v(lo, vc);
                        for (block, nv) in vc.chunks(bs).zip(nc.iter_mut()) {
                            *nv = crate::compression::kernel::max_abs(block);
                        }
                    });
                }
                _ => {
                    let items: Vec<(usize, &mut [F], &mut f64)> = self
                        .v
                        .chunks_mut(shard)
                        .zip(vsq.iter_mut())
                        .enumerate()
                        .map(|(c, (vc, sq))| (c * shard, vc, sq))
                        .collect();
                    pool.run(items, |(lo, vc, sq)| {
                        *sq = fill_v(lo, vc);
                    });
                }
            }
        }
        // lint:allow(float_fold, folds shard partials in slot order; shard count is thread-independent)
        self.last_norm = vsq.iter().sum::<f64>().sqrt();
        // the downlink, compressed over the same shards (bit-identical
        // payload + RNG stream to the serial compress), reusing the fused
        // norms when the sweep produced them
        let down = match fused_norms {
            Some(norms) => self.mq.compress_with_norms(&self.v, norms, rng, &pool),
            None => self.mq.compress_sharded(&self.v, rng, &pool),
        };
        // E = v − Q(v);  x ← x − Q(v);  x ← prox_{γR}(x) — one fused
        // sharded sweep running the fixed-width residual kernel (prox is
        // separable, so the serial tail folds into the same pass).
        let gamma = self.hp.lr_at(round);
        let prox = self.hp.prox;
        {
            let (err, x) = (&mut self.err, &mut self.x);
            let v = &self.v;
            let down_ref = &down;
            pool.sweep2(err, x, |lo, ec, xc| {
                down_ref.fold_residual_range(lo, &v[lo..lo + ec.len()], -1.0, ec, xc);
                for xv in xc.iter_mut() {
                    *xv = prox.apply_one(gamma, *xv);
                }
            });
        }
        down
    }

    fn model(&self) -> &[F] {
        &self.x
    }

    fn export_state(&self) -> Vec<(String, Vec<F>)> {
        vec![("E".into(), self.err.clone())]
    }

    fn import_state(&mut self, model: &[F], aux: &[(String, Vec<F>)]) -> anyhow::Result<()> {
        super::restore_vec("x", &mut self.x, model)?;
        for (name, v) in aux {
            match name.as_str() {
                "E" => super::restore_vec("E", &mut self.err, v)?,
                other => anyhow::bail!("unknown aux vector '{other}' for the DoubleSqueeze master"),
            }
        }
        Ok(())
    }

    fn set_reduce_pool(&mut self, pool: ReducePool) {
        self.pool = pool;
    }

    fn last_compressed_norm(&self) -> f64 {
        self.last_norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Identity, TopK};
    use std::sync::Arc;

    #[test]
    fn identity_compression_reduces_to_sgd() {
        let x0 = vec![1.0, -1.0];
        let hp = HyperParams { lr: 0.25, ..HyperParams::paper_defaults() };
        let mut w = DsWorker::new(&x0, Arc::new(Identity), hp.clone());
        let mut m = DsMaster::new(&x0, 1, Arc::new(Identity), hp);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let up = w.round(0, &[4.0, 8.0], &mut rng);
        let down = m.round(0, &[Some(up)], &mut rng);
        w.apply_downlink(0, &down);
        assert_eq!(m.model(), &[0.0, -3.0]);
        assert_eq!(w.model(), m.model());
        assert!(w.e.iter().all(|&v| v == 0.0));
        assert!(m.err.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn errors_are_conserved() {
        // invariant: Q(p) + e_new == p  and  Q(v) + E_new == v
        let x0 = vec![0.0; 10];
        let hp = HyperParams { lr: 0.5, ..HyperParams::paper_defaults() };
        let mut w = DsWorker::new(&x0, Arc::new(TopK::new(3)), hp.clone());
        let mut m = DsMaster::new(&x0, 1, Arc::new(TopK::new(3)), hp);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let g: Vec<F> = (0..10).map(|i| (i as F * 0.7).sin()).collect();
        let p_expect: Vec<F> = g.iter().map(|&v| 0.5 * v).collect(); // e=0 first round
        let up = w.round(0, &g, &mut rng);
        let mut rec = w.e.clone();
        up.add_scaled_into(1.0, &mut rec);
        for (r, p) in rec.iter().zip(&p_expect) {
            assert!((r - p).abs() < 1e-6);
        }
        let v_before = {
            let mut v = vec![0.0; 10];
            up.add_scaled_into(1.0, &mut v);
            v
        };
        let down = m.round(0, &[Some(up)], &mut rng);
        let mut rec2 = m.err.clone();
        down.add_scaled_into(1.0, &mut rec2);
        for (r, p) in rec2.iter().zip(&v_before) {
            assert!((r - p).abs() < 1e-6);
        }
    }

    #[test]
    fn worker_and_master_models_stay_identical() {
        let x0 = vec![0.0; 16];
        let hp = HyperParams { lr: 0.1, ..HyperParams::paper_defaults() };
        let wq = crate::compression::from_spec("ternary:8").unwrap();
        let mq = crate::compression::from_spec("ternary:8").unwrap();
        let mut w = DsWorker::new(&x0, wq, hp.clone());
        let mut m = DsMaster::new(&x0, 1, mq, hp);
        for k in 0..10u64 {
            let g: Vec<F> = (0..16).map(|j| ((j as u64 + k) as F).cos()).collect();
            let mut wr = Xoshiro256::for_site(9, 1, k);
            let up = w.round(k as usize, &g, &mut wr);
            let mut mr = Xoshiro256::for_site(9, 0, k);
            let down = m.round(k as usize, &[Some(up)], &mut mr);
            w.apply_downlink(k as usize, &down);
            for (a, b) in w.model().iter().zip(m.model()) {
                assert!((a - b).abs() < 1e-6, "model desync at round {k}");
            }
        }
    }
}
