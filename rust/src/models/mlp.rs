//! Pure-rust MLP classifier with softmax cross-entropy and backprop — the
//! nonconvex workload standing in for LeNet (Fig. 4) and ResNet18 (Fig. 5).
//!
//! The parameter vector is the flat concatenation of `(W_l, b_l)` per layer
//! (row-major `in × out` weights), so the distributed algorithms treat it as
//! an opaque `R^d` exactly as they would a deep net. Minibatch gradients are
//! computed with the GEMM kernels in [`super::linalg`].
//!
//! The PJRT-backed twin of this model (same architecture, JAX-lowered HLO)
//! lives in `python/compile/model.py` + [`crate::runtime`]; an integration
//! test checks the two gradients agree.

use super::linalg::{gemm, gemm_a_bt, gemm_at_b};
use super::Problem;
use crate::compression::Xoshiro256;
use crate::data::{shard_ranges, Dataset};
use crate::F;

/// Layer sizes, e.g. `[784, 256, 64, 10]`.
#[derive(Clone, Debug)]
pub struct MlpArch {
    pub sizes: Vec<usize>,
}

impl MlpArch {
    pub fn new(sizes: &[usize]) -> Self {
        assert!(sizes.len() >= 2);
        Self { sizes: sizes.to_vec() }
    }

    /// Total parameter count (weights + biases).
    pub fn dim(&self) -> usize {
        self.sizes
            .windows(2)
            .map(|w| w[0] * w[1] + w[1])
            .sum()
    }

    /// Offsets of each layer's `(W, b)` in the flat vector.
    pub fn offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut off = 0;
        for w in self.sizes.windows(2) {
            let wlen = w[0] * w[1];
            out.push((off, off + wlen));
            off += wlen + w[1];
        }
        out
    }

    /// He-uniform initialization, identical on every node for a fixed seed
    /// (§3.2 Initialization: all nodes start from the same `x̂⁰`).
    pub fn init(&self, seed: u64) -> Vec<F> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut x = vec![0.0; self.dim()];
        for ((woff, boff), w) in self.offsets().into_iter().zip(self.sizes.windows(2)) {
            let bound = (6.0 / w[0] as F).sqrt();
            for v in x[woff..boff].iter_mut() {
                *v = (rng.next_f32() * 2.0 - 1.0) * bound;
            }
            // biases stay zero
        }
        x
    }
}

pub struct Mlp {
    pub arch: MlpArch,
    pub train: Dataset,
    pub test: Option<Dataset>,
    pub n_workers: usize,
    shards: Vec<(usize, usize)>,
    init_seed: u64,
}

impl Mlp {
    pub fn new(
        arch: MlpArch,
        train: Dataset,
        test: Option<Dataset>,
        n_workers: usize,
        init_seed: u64,
    ) -> Self {
        assert_eq!(arch.sizes[0], train.input_dim);
        assert_eq!(*arch.sizes.last().unwrap(), train.n_classes);
        let shards = shard_ranges(train.n, n_workers);
        Self { arch, train, test, n_workers, shards, init_seed }
    }

    /// Forward pass over a batch; returns per-layer pre-activations needed
    /// by backprop plus mean CE loss. `acts[0]` is the input batch.
    fn forward(&self, x: &[F], batch: &[usize]) -> (Vec<Vec<F>>, f64, usize) {
        let bsz = batch.len();
        let sizes = &self.arch.sizes;
        let nl = sizes.len() - 1;
        let offs = self.arch.offsets();
        let mut acts: Vec<Vec<F>> = Vec::with_capacity(nl + 1);
        let mut input = vec![0.0; bsz * sizes[0]];
        for (r, &ex) in batch.iter().enumerate() {
            input[r * sizes[0]..(r + 1) * sizes[0]].copy_from_slice(self.train.example(ex).0);
        }
        acts.push(input);
        for l in 0..nl {
            let (wo, bo) = offs[l];
            let w = &x[wo..bo];
            let b = &x[bo..bo + sizes[l + 1]];
            let mut z = vec![0.0; bsz * sizes[l + 1]];
            gemm(bsz, sizes[l], sizes[l + 1], &acts[l], w, &mut z, false);
            for row in z.chunks_mut(sizes[l + 1]) {
                for (zi, &bi) in row.iter_mut().zip(b.iter()) {
                    *zi += bi;
                }
            }
            if l + 1 < nl {
                for v in z.iter_mut() {
                    *v = v.max(0.0); // ReLU
                }
            }
            acts.push(z);
        }
        // softmax CE on the logits
        let k = sizes[nl];
        let logits = acts.last_mut().unwrap();
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for (r, &ex) in batch.iter().enumerate() {
            let row = &mut logits[r * k..(r + 1) * k];
            let y = self.train.labels[ex] as usize;
            let mx = row.iter().fold(F::NEG_INFINITY, |m, &v| m.max(v));
            let mut argmax = 0;
            for (j, &v) in row.iter().enumerate() {
                if v == mx {
                    argmax = j;
                }
            }
            if argmax == y {
                correct += 1;
            }
            let sum: F = row.iter().map(|&v| (v - mx).exp()).sum();
            loss += (sum.ln() + mx - row[y]) as f64;
            // replace logits with softmax − onehot = dL/dz (scaled later)
            for (j, v) in row.iter_mut().enumerate() {
                *v = (*v - mx).exp() / sum - if j == y { 1.0 } else { 0.0 };
            }
        }
        (acts, loss / bsz as f64, correct)
    }

    /// Backprop: fills `gout` with the mean gradient over `batch`.
    fn backward(&self, x: &[F], acts: &mut [Vec<F>], batch_len: usize, gout: &mut [F]) {
        let sizes = &self.arch.sizes;
        let nl = sizes.len() - 1;
        let offs = self.arch.offsets();
        let inv_b = 1.0 / batch_len as F;
        // delta starts as (softmax − onehot)/B, already stored in acts[nl]
        let mut delta = std::mem::take(&mut acts[nl]);
        for v in delta.iter_mut() {
            *v *= inv_b;
        }
        for l in (0..nl).rev() {
            let (wo, bo) = offs[l];
            let (din, dout) = (sizes[l], sizes[l + 1]);
            // dW = acts[l]^T · delta  (in × out)
            gemm_at_b(din, batch_len, dout, &acts[l], &delta, &mut gout[wo..bo]);
            // db = column sums of delta
            let gb = &mut gout[bo..bo + dout];
            gb.fill(0.0);
            for row in delta.chunks(dout) {
                for (g, &d) in gb.iter_mut().zip(row.iter()) {
                    *g += d;
                }
            }
            if l > 0 {
                // delta_prev = (delta · W^T) ⊙ relu'(z_{l-1})
                let w = &x[wo..bo];
                let mut prev = vec![0.0; batch_len * din];
                gemm_a_bt(batch_len, dout, din, &delta, w, &mut prev);
                for (p, &z) in prev.iter_mut().zip(acts[l].iter()) {
                    if z <= 0.0 {
                        *p = 0.0;
                    }
                }
                delta = prev;
            }
        }
    }

    fn eval(&self, ds: &Dataset, x: &[F]) -> (f64, f64) {
        // forward over the dataset in chunks, reusing the train-forward by
        // temporarily borrowing examples — simplest: inline fwd here.
        let sizes = &self.arch.sizes;
        let nl = sizes.len() - 1;
        let offs = self.arch.offsets();
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let chunk = 128;
        for lo in (0..ds.n).step_by(chunk) {
            let hi = (lo + chunk).min(ds.n);
            let bsz = hi - lo;
            let mut act = vec![0.0; bsz * sizes[0]];
            for r in 0..bsz {
                act[r * sizes[0]..(r + 1) * sizes[0]].copy_from_slice(ds.example(lo + r).0);
            }
            for l in 0..nl {
                let (wo, bo) = offs[l];
                let mut z = vec![0.0; bsz * sizes[l + 1]];
                gemm(bsz, sizes[l], sizes[l + 1], &act, &x[wo..bo], &mut z, false);
                for row in z.chunks_mut(sizes[l + 1]) {
                    for (zi, &bi) in row.iter_mut().zip(x[bo..bo + sizes[l + 1]].iter()) {
                        *zi += bi;
                    }
                }
                if l + 1 < nl {
                    for v in z.iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                act = z;
            }
            let k = sizes[nl];
            for r in 0..bsz {
                let row = &act[r * k..(r + 1) * k];
                let y = ds.labels[lo + r] as usize;
                let mx = row.iter().fold(F::NEG_INFINITY, |m, &v| m.max(v));
                let mut am = 0;
                for (j, &v) in row.iter().enumerate() {
                    if v == mx {
                        am = j;
                    }
                }
                if am == y {
                    correct += 1;
                }
                let sum: F = row.iter().map(|&v| (v - mx).exp()).sum();
                loss += (sum.ln() + mx - row[y]) as f64;
            }
        }
        (loss / ds.n as f64, correct as f64 / ds.n as f64)
    }
}

impl Problem for Mlp {
    fn dim(&self) -> usize {
        self.arch.dim()
    }

    fn n_workers(&self) -> usize {
        self.n_workers
    }

    fn local_grad(
        &self,
        i: usize,
        x: &[F],
        minibatch: Option<usize>,
        rng: &mut Xoshiro256,
        out: &mut [F],
    ) {
        let (lo, hi) = self.shards[i];
        let batch: Vec<usize> = match minibatch {
            None => (lo..hi).collect(),
            Some(m) => (0..m).map(|_| lo + rng.next_below(hi - lo)).collect(),
        };
        let (mut acts, _, _) = self.forward(x, &batch);
        self.backward(x, &mut acts, batch.len(), out);
    }

    fn loss(&self, x: &[F]) -> f64 {
        self.eval(&self.train, x).0
    }

    fn test_loss(&self, x: &[F]) -> Option<f64> {
        self.test.as_ref().map(|t| self.eval(t, x).0)
    }

    fn test_accuracy(&self, x: &[F]) -> Option<f64> {
        self.test.as_ref().map(|t| self.eval(t, x).1)
    }

    fn init(&self) -> Vec<F> {
        self.arch.init(self.init_seed)
    }

    fn name(&self) -> &str {
        "mlp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::cluster_classification;

    fn tiny_mlp() -> Mlp {
        let ds = cluster_classification(64, 12, 4, 1.0, 3);
        Mlp::new(MlpArch::new(&[12, 16, 4]), ds, None, 2, 1)
    }

    #[test]
    fn dims_and_offsets_consistent() {
        let arch = MlpArch::new(&[784, 256, 64, 10]);
        assert_eq!(arch.dim(), 784 * 256 + 256 + 256 * 64 + 64 + 64 * 10 + 10);
        let offs = arch.offsets();
        assert_eq!(offs.len(), 3);
        assert_eq!(offs[0], (0, 784 * 256));
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = tiny_mlp();
        let x = m.init();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut g = vec![0.0; m.dim()];
        m.local_grad(0, &x, None, &mut rng, &mut g);
        // loss restricted to worker 0's shard:
        let (lo, hi) = m.shards[0];
        let batch: Vec<usize> = (lo..hi).collect();
        let f = |xv: &[F]| m.forward(xv, &batch).1;
        let eps = 1e-2;
        // check a scattering of coordinates across layers
        for &j in &[0usize, 5, 12 * 16 + 3, 12 * 16 + 16 + 7, m.dim() - 1] {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[j] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "coord {j}: fd {fd} vs bp {}",
                g[j]
            );
        }
    }

    #[test]
    fn training_reduces_loss() {
        let m = tiny_mlp();
        let mut x = m.init();
        let l0 = m.loss(&x);
        let mut g = vec![0.0; m.dim()];
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..60 {
            // full-batch GD on worker 0+1 average
            let mut acc = vec![0.0; m.dim()];
            for w in 0..2 {
                m.local_grad(w, &x, None, &mut rng, &mut g);
                crate::models::linalg::axpy(0.5, &g, &mut acc);
            }
            crate::models::linalg::axpy(-0.5, &acc, &mut x);
        }
        let l1 = m.loss(&x);
        assert!(l1 < 0.5 * l0, "loss {l0} -> {l1}");
    }

    #[test]
    fn init_is_deterministic_across_nodes() {
        let m = tiny_mlp();
        assert_eq!(m.init(), m.init());
    }

    #[test]
    fn eval_accuracy_in_unit_range() {
        let ds = cluster_classification(80, 12, 4, 1.0, 3);
        let (tr, te) = ds.split_test(20);
        let m = Mlp::new(MlpArch::new(&[12, 16, 4]), tr, Some(te), 2, 1);
        let x = m.init();
        let acc = m.test_accuracy(&x).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert!(m.test_loss(&x).unwrap() > 0.0);
    }
}
