//! The paper's strongly convex benchmark (§5.1): ridge-regularized linear
//! regression `f(x) = (1/N)‖Ax − b‖² + λ‖x‖²`, rows of `A` allocated evenly
//! to `n` workers, so `f_i(x) = (1/N_i)‖A_i x − b_i‖² + λ‖x‖²` and
//! `f = (1/n) Σ f_i` for even shards. The exact minimizer
//! `x* = (AᵀA/N + λI)⁻¹ Aᵀb/N` is computed by Cholesky at construction,
//! enabling the `‖x̂ − x*‖` curves of Fig. 3/6 and the empirical linear-rate
//! estimates of Table 1.

use super::linalg;
use super::Problem;
use crate::compression::Xoshiro256;
use crate::F;

pub struct LinReg {
    /// `N × d` design matrix, row-major.
    pub a: Vec<F>,
    /// Targets, length `N`.
    pub b: Vec<F>,
    pub rows: usize,
    pub dim: usize,
    /// Ridge coefficient λ (part of `f`, not of the proximal `R`).
    pub lambda: F,
    pub n_workers: usize,
    /// Closed-form minimizer.
    x_star: Vec<F>,
    /// `f(x*)` — subtracted to report the optimality gap `f(x) − f*`.
    f_star: f64,
}

impl LinReg {
    pub fn new(a: Vec<F>, b: Vec<F>, rows: usize, dim: usize, lambda: F, n_workers: usize) -> Self {
        assert_eq!(a.len(), rows * dim);
        assert_eq!(b.len(), rows);
        assert!(n_workers > 0 && rows % n_workers == 0, "rows must shard evenly");
        // Normal equations: (AᵀA/N + λI) x* = Aᵀ b / N.
        let mut m = vec![0.0; dim * dim];
        linalg::gemm_at_b(dim, rows, dim, &a, &a, &mut m);
        let inv_n = 1.0 / rows as F;
        for v in m.iter_mut() {
            *v *= inv_n;
        }
        for i in 0..dim {
            m[i * dim + i] += lambda;
        }
        let mut rhs = vec![0.0; dim];
        linalg::matvec_t(&a, rows, dim, &b, &mut rhs);
        linalg::scal(inv_n, &mut rhs);
        let x_star = linalg::cholesky_solve(&m, dim, &rhs);
        let mut s = Self {
            a,
            b,
            rows,
            dim,
            lambda,
            n_workers,
            x_star,
            f_star: 0.0,
        };
        s.f_star = s.raw_loss(&s.x_star);
        s
    }

    /// Rows `[lo, hi)` of worker `i`'s shard.
    fn shard(&self, i: usize) -> (usize, usize) {
        let per = self.rows / self.n_workers;
        (i * per, (i + 1) * per)
    }

    fn raw_loss(&self, x: &[F]) -> f64 {
        let mut r = vec![0.0; self.rows];
        linalg::matvec(&self.a, self.rows, self.dim, x, &mut r);
        let mut s = 0.0f64;
        for (ri, &bi) in r.iter().zip(self.b.iter()) {
            let d = (*ri - bi) as f64;
            s += d * d;
        }
        s / self.rows as f64 + self.lambda as f64 * linalg::norm2sq(x)
    }

    /// Smoothness / strong-convexity constants of the *global* objective:
    /// `L = 2 λ_max(AᵀA/N) + 2λ`, `μ = 2 λ_min(AᵀA/N) + 2λ` (power/inverse
    /// iteration estimates). Used to pick the paper's theoretical step size.
    pub fn smoothness(&self) -> (f64, f64) {
        let d = self.dim;
        let mut m = vec![0.0; d * d];
        linalg::gemm_at_b(d, self.rows, d, &self.a, &self.a, &mut m);
        let inv_n = 1.0 / self.rows as F;
        for v in m.iter_mut() {
            *v *= inv_n;
        }
        // power iteration for λ_max
        let mut v = vec![1.0 as F; d];
        let mut lmax = 0.0f64;
        for _ in 0..200 {
            let mut w = vec![0.0; d];
            linalg::matvec(&m, d, d, &v, &mut w);
            lmax = linalg::norm2(&w);
            let inv = 1.0 / lmax.max(1e-30) as F;
            for (vi, &wi) in v.iter_mut().zip(w.iter()) {
                *vi = wi * inv;
            }
        }
        // λ_min via power iteration on (λ_max I − M)
        let mut v2 = vec![1.0 as F; d];
        v2[0] = -1.0;
        let mut shift_max = 0.0f64;
        for _ in 0..400 {
            let mut w = vec![0.0; d];
            linalg::matvec(&m, d, d, &v2, &mut w);
            for i in 0..d {
                w[i] = lmax as F * v2[i] - w[i];
            }
            shift_max = linalg::norm2(&w);
            let inv = 1.0 / shift_max.max(1e-30) as F;
            for (vi, &wi) in v2.iter_mut().zip(w.iter()) {
                *vi = wi * inv;
            }
        }
        let lmin = (lmax - shift_max).max(0.0);
        (
            2.0 * lmax + 2.0 * self.lambda as f64,
            2.0 * lmin + 2.0 * self.lambda as f64,
        )
    }
}

impl Problem for LinReg {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_workers(&self) -> usize {
        self.n_workers
    }

    fn local_grad(
        &self,
        i: usize,
        x: &[F],
        minibatch: Option<usize>,
        rng: &mut Xoshiro256,
        out: &mut [F],
    ) {
        let (lo, hi) = self.shard(i);
        let d = self.dim;
        out.fill(0.0);
        let rows: Vec<usize> = match minibatch {
            None => (lo..hi).collect(),
            Some(m) => (0..m).map(|_| lo + rng.next_below(hi - lo)).collect(),
        };
        // ∇f_i = (2/m) Σ_r (a_rᵀx − b_r) a_r + 2λx
        let scale = 2.0 / rows.len() as F;
        for &r in &rows {
            let row = &self.a[r * d..(r + 1) * d];
            let resid = (linalg::dot(row, x) as F - self.b[r]) * scale;
            linalg::axpy(resid, row, out);
        }
        linalg::axpy(2.0 * self.lambda, x, out);
    }

    /// Optimality gap `f(x) − f(x*)` (the quantity Fig. 3 plots).
    fn loss(&self, x: &[F]) -> f64 {
        (self.raw_loss(x) - self.f_star).max(0.0)
    }

    fn optimum(&self) -> Option<&[F]> {
        Some(&self.x_star)
    }

    fn name(&self) -> &str {
        "linreg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::linreg_problem;

    #[test]
    fn gradient_vanishes_at_optimum() {
        let p = linreg_problem(120, 20, 4, 0.1, 7);
        let xs = p.optimum().unwrap().to_vec();
        // average of full local gradients should be ~0 at x*
        let mut g = vec![0.0; p.dim()];
        let mut acc = vec![0.0; p.dim()];
        let mut rng = Xoshiro256::seed_from_u64(0);
        for i in 0..p.n_workers() {
            p.local_grad(i, &xs, None, &mut rng, &mut g);
            linalg::axpy(1.0 / p.n_workers() as F, &g, &mut acc);
        }
        assert!(linalg::norm2(&acc) < 1e-3, "‖∇f(x*)‖ = {}", linalg::norm2(&acc));
    }

    #[test]
    fn full_grad_equals_average_of_shards() {
        // one worker holding everything == average of 4 workers' gradients
        let p4 = linreg_problem(120, 20, 4, 0.1, 7);
        let p1 = LinReg::new(p4.a.clone(), p4.b.clone(), 120, 20, 0.1, 1);
        let x: Vec<F> = (0..20).map(|i| (i as F * 0.37).sin()).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut g1 = vec![0.0; 20];
        p1.local_grad(0, &x, None, &mut rng, &mut g1);
        let mut avg = vec![0.0; 20];
        let mut g = vec![0.0; 20];
        for i in 0..4 {
            p4.local_grad(i, &x, None, &mut rng, &mut g);
            linalg::axpy(0.25, &g, &mut avg);
        }
        for (a, b) in g1.iter().zip(&avg) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn loss_gap_zero_at_optimum_positive_elsewhere() {
        let p = linreg_problem(60, 10, 3, 0.05, 1);
        let xs = p.optimum().unwrap().to_vec();
        assert!(p.loss(&xs) < 1e-9);
        let x0 = vec![0.0; 10];
        assert!(p.loss(&x0) > 1e-3);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = linreg_problem(40, 8, 2, 0.2, 3);
        let x: Vec<F> = (0..8).map(|i| 0.1 * i as F - 0.3).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        // global grad = avg of local grads; check against FD of raw_loss
        let mut g = vec![0.0; 8];
        let mut acc = vec![0.0f64; 8];
        for i in 0..2 {
            p.local_grad(i, &x, None, &mut rng, &mut g);
            for (a, &gi) in acc.iter_mut().zip(g.iter()) {
                *a += gi as f64 / 2.0;
            }
        }
        let eps = 1e-3;
        for j in 0..8 {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fd = (p.raw_loss(&xp) - p.raw_loss(&xm)) / (2.0 * eps as f64);
            assert!((fd - acc[j]).abs() < 2e-2, "coord {j}: fd {fd} vs {})", acc[j]);
        }
    }

    #[test]
    fn smoothness_constants_sane() {
        let p = linreg_problem(200, 30, 4, 0.1, 11);
        let (l, mu) = p.smoothness();
        assert!(l >= mu && mu > 0.0, "L={l} mu={mu}");
        // ridge alone contributes 2λ to both
        assert!(mu >= 2.0 * 0.1 - 1e-6);
    }
}
