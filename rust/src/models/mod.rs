//! Problem substrates: the objective functions the distributed algorithms
//! optimize.
//!
//! * [`linreg`] — the paper's §5.1 strongly convex benchmark
//!   `f(x) = (1/N)||Ax − b||² + λ||x||²` with a closed-form optimum
//!   (dense Cholesky in [`linalg`]), row-sharded over workers.
//! * [`mlp`] — a pure-rust multi-layer perceptron classifier with backprop,
//!   the nonconvex workload standing in for LeNet/ResNet18 (see DESIGN.md
//!   §Hardware-Adaptation).
//! * [`Problem`] — the trait the coordinator and bench harness consume; the
//!   PJRT-backed problems in [`crate::runtime`] implement it too, so the
//!   same algorithms drive rust-native oracles and AOT XLA executables.

pub mod linalg;
pub mod linreg;
pub mod mlp;

use crate::compression::Xoshiro256;
use crate::F;

/// A distributed optimization problem: `f(x) = (1/n) Σ_i f_i(x) (+ R(x))`,
/// where worker `i` can evaluate stochastic gradients of its local `f_i`.
pub trait Problem: Send + Sync {
    /// Model dimension `d`.
    fn dim(&self) -> usize;

    /// Number of workers the data is sharded over.
    fn n_workers(&self) -> usize;

    /// Write worker `i`'s stochastic gradient of `f_i` at `x` into `out`.
    /// `minibatch = None` requests the full local gradient (σ = 0, as in
    /// the paper's Fig. 3 experiment); `Some(m)` samples `m` examples from
    /// the worker's shard using `rng`.
    fn local_grad(
        &self,
        i: usize,
        x: &[F],
        minibatch: Option<usize>,
        rng: &mut Xoshiro256,
        out: &mut [F],
    );

    /// Global training objective `f(x)` (excluding any proximal `R`).
    fn loss(&self, x: &[F]) -> f64;

    /// Held-out loss, if the problem has a test split.
    fn test_loss(&self, _x: &[F]) -> Option<f64> {
        None
    }

    /// Classification accuracy on the test split, if applicable.
    fn test_accuracy(&self, _x: &[F]) -> Option<f64> {
        None
    }

    /// The exact minimizer, when computable (linreg): enables `‖x − x*‖`
    /// curves (Fig. 3) and empirical linear-rate estimation (Table 1).
    fn optimum(&self) -> Option<&[F]> {
        None
    }

    /// Initial iterate `x̂⁰` (identical across nodes — §3.2 Initialization).
    fn init(&self) -> Vec<F> {
        vec![0.0; self.dim()]
    }

    fn name(&self) -> &str;
}
