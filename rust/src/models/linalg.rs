//! Minimal dense linear algebra used by the model substrates: BLAS-1
//! helpers, a cache-blocked GEMM for the MLP, and a Cholesky solver for the
//! linreg closed-form optimum. No external dependencies — this *is* the
//! substrate.

use crate::F;

/// `y += a * x`
#[inline]
pub fn axpy(a: F, x: &[F], y: &mut [F]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// `x · y`
#[inline]
pub fn dot(x: &[F], y: &[F]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y.iter()).map(|(&a, &b)| a as f64 * b as f64).sum()
}

/// `‖x‖₂²`
#[inline]
pub fn norm2sq(x: &[F]) -> f64 {
    dot(x, x)
}

/// `‖x‖₂`
#[inline]
pub fn norm2(x: &[F]) -> f64 {
    norm2sq(x).sqrt()
}

/// `‖x − y‖₂`
pub fn dist2(x: &[F], y: &[F]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y.iter())
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Scale in place: `x *= a`.
#[inline]
pub fn scal(a: F, x: &mut [F]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Row-major mat-vec: `out = A x`, `A` is `rows × cols`.
pub fn matvec(a: &[F], rows: usize, cols: usize, x: &[F], out: &mut [F]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(x.len(), cols);
    debug_assert_eq!(out.len(), rows);
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot(&a[r * cols..(r + 1) * cols], x) as F;
    }
}

/// Row-major transposed mat-vec: `out = Aᵀ y`.
pub fn matvec_t(a: &[F], rows: usize, cols: usize, y: &[F], out: &mut [F]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(y.len(), rows);
    debug_assert_eq!(out.len(), cols);
    out.fill(0.0);
    for (r, &yr) in y.iter().enumerate() {
        axpy(yr, &a[r * cols..(r + 1) * cols], out);
    }
}

/// Row-major GEMM `C = A·B (+ C if accumulate)`, `A: m×k`, `B: k×n`,
/// `C: m×n`. ikj loop order with the inner j-loop vectorizable; good enough
/// for the MLP substrate (hundreds of MFLOPs per bench step).
pub fn gemm(
    m: usize,
    k: usize,
    n: usize,
    a: &[F],
    b: &[F],
    c: &mut [F],
    accumulate: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += aip * bj;
            }
        }
    }
}

/// `C = Aᵀ·B`, `A: k×m`, `B: k×n`, `C: m×n` (used for weight gradients).
pub fn gemm_at_b(m: usize, k: usize, n: usize, a: &[F], b: &[F], c: &mut [F]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += aip * bj;
            }
        }
    }
}

/// `C = A·Bᵀ`, `A: m×k`, `B: n×k`, `C: m×n` (used for backprop through W).
pub fn gemm_a_bt(m: usize, k: usize, n: usize, a: &[F], b: &[F], c: &mut [F]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            c[i * n + j] = dot(arow, &b[j * k..(j + 1) * k]) as F;
        }
    }
}

/// Solve the SPD system `M z = rhs` by Cholesky (`M = L Lᵀ`), in-place on a
/// copy. `M` is `d × d` row-major. Panics if `M` is not positive definite.
pub fn cholesky_solve(m: &[F], d: usize, rhs: &[F]) -> Vec<F> {
    debug_assert_eq!(m.len(), d * d);
    debug_assert_eq!(rhs.len(), d);
    // factor in f64 for stability on ill-conditioned AᵀA
    let mut l = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..=i {
            let mut s = m[i * d + j] as f64;
            for p in 0..j {
                s -= l[i * d + p] * l[j * d + p];
            }
            if i == j {
                assert!(s > 0.0, "matrix not positive definite at pivot {i} (s={s})");
                l[i * d + i] = s.sqrt();
            } else {
                l[i * d + j] = s / l[j * d + j];
            }
        }
    }
    // forward substitution L y = rhs
    let mut y = vec![0.0f64; d];
    for i in 0..d {
        let mut s = rhs[i] as f64;
        for p in 0..i {
            s -= l[i * d + p] * y[p];
        }
        y[i] = s / l[i * d + i];
    }
    // back substitution Lᵀ z = y
    let mut z = vec![0.0f64; d];
    for i in (0..d).rev() {
        let mut s = y[i];
        for p in i + 1..d {
            s -= l[p * d + i] * z[p];
        }
        z[i] = s / l[i * d + i];
    }
    z.into_iter().map(|v| v as F).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blas1_basics() {
        let mut y = vec![1.0, 2.0];
        axpy(2.0, &[3.0, -1.0], &mut y);
        assert_eq!(y, vec![7.0, 0.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((dist2(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn matvec_and_transpose() {
        // A = [[1,2,3],[4,5,6]]
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = vec![0.0; 2];
        matvec(&a, 2, 3, &[1.0, 0.0, -1.0], &mut out);
        assert_eq!(out, vec![-2.0, -2.0]);
        let mut out_t = vec![0.0; 3];
        matvec_t(&a, 2, 3, &[1.0, 1.0], &mut out_t);
        assert_eq!(out_t, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn gemm_variants_agree() {
        let m = 3;
        let k = 4;
        let n = 2;
        let a: Vec<F> = (0..m * k).map(|i| i as F * 0.5 - 2.0).collect();
        let b: Vec<F> = (0..k * n).map(|i| 1.0 - i as F * 0.25).collect();
        let mut c = vec![0.0; m * n];
        gemm(m, k, n, &a, &b, &mut c, false);
        // reference
        for i in 0..m {
            for j in 0..n {
                let want: F = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                assert!((c[i * n + j] - want).abs() < 1e-5);
            }
        }
        // A^T B against gemm on transposed data
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut c2 = vec![0.0; m * n];
        gemm_at_b(m, k, n, &at, &b, &mut c2);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-5);
        }
        // A B^T
        let mut bt = vec![0.0; n * k];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut c3 = vec![0.0; m * n];
        gemm_a_bt(m, k, n, &a, &bt, &mut c3);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn cholesky_solves_spd_system() {
        // M = B^T B + I is SPD
        let d = 5;
        let mut rng = crate::compression::Xoshiro256::seed_from_u64(42);
        let b: Vec<F> = (0..d * d).map(|_| rng.next_gaussian()).collect();
        let mut m = vec![0.0; d * d];
        gemm_at_b(d, d, d, &b, &b, &mut m);
        for i in 0..d {
            m[i * d + i] += 1.0;
        }
        let z_true: Vec<F> = (0..d).map(|i| i as F - 2.0).collect();
        let mut rhs = vec![0.0; d];
        matvec(&m, d, d, &z_true, &mut rhs);
        let z = cholesky_solve(&m, d, &rhs);
        for (a, b) in z.iter().zip(&z_true) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    #[should_panic(expected = "not positive definite")]
    fn cholesky_rejects_indefinite() {
        let m = vec![1.0, 0.0, 0.0, -1.0];
        cholesky_solve(&m, 2, &[1.0, 1.0]);
    }
}
